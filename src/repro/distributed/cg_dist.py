"""Distributed JPCG — row-partitioned shard_map solver at pod scale.

Decomposition (DESIGN.md §5): rows of A block-partitioned over the
flattened mesh ("rows" = data × model [× pod]); every vector (r, p, x)
lives sharded by row.  Per iteration:

* **SpMV** — each shard holds a banked-ELL slice with *global* column
  tiles; ``all_gather`` assembles the x-window (stencil matrices could use
  a neighbor ``ppermute`` halo instead — ``halo_width`` in the partition
  metadata says when; all-gather is the general correct path and is what
  the roofline accounts).
* **dots** — local partial then ``psum``: the FPGA's scalar FIFO to the
  global controller becomes an ICI all-reduce.
* **paper schedule (vsr)** — two psums per iteration (α and β barriers),
  exactly Callipepla's two scalar barriers.
* **pipelined** — the beyond-paper variant: ONE psum of a packed
  length-4 vector per iteration ([γ, δ, ‖r‖², pap-guard]), overlapped
  with the next SpMV by XLA's scheduler.  At 512 chips the α/β reductions
  are latency-bound, so halving their count halves the collective term.

Termination stays on-the-fly: the while_loop predicate reads the psum'd
``rr`` — every shard sees the same scalar, so control flow is coherent
without a host round-trip (paper Challenge 1 at pod scale).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                   # moved to jax.shard_map upstream
    _shard_map = jax.shard_map
except AttributeError:                 # pre-move JAX: the experimental
    from functools import partial as _partial  # shard_map has no while_loop
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    # replication rule — disable the check (the solver psums every scalar
    # the predicate reads, so replication is correct by construction)
    _shard_map = _partial(_exp_shard_map, check_rep=False)

from repro.core.operators import bell_spmv_jnp
from repro.core.precision import PrecisionScheme, get_scheme
from repro.sparse.partition import PartitionedMatrix, partition_rows

__all__ = ["DistCG", "make_dist_solver"]

AXIS = "rows"


@dataclasses.dataclass(frozen=True)
class DistCG:
    """Compiled distributed solver bound to a mesh + partitioned matrix."""
    mesh: Mesh
    part: PartitionedMatrix
    scheme: PrecisionScheme
    method: str
    solve: callable            # (b, x0, diag) -> (x, iters, rr)


def _local_spmv(shard_args, x_full, *, block_rows, col_tile, scheme, n_pad):
    # shard_map keeps the sharded leading axis at local size 1 — drop it.
    tile_cols, vals, lrows, lcols = (a[0] for a in shard_args)
    if x_full.shape[0] >= n_pad:          # row padding exceeds col padding
        x_pad = x_full[:n_pad]
    else:
        x_pad = jnp.zeros(n_pad, x_full.dtype).at[: x_full.shape[0]].set(
            x_full)
    return bell_spmv_jnp(tile_cols, vals, lrows, lcols, x_pad,
                         block_rows=block_rows, col_tile=col_tile,
                         scheme=scheme)


def make_dist_solver(a, mesh: Mesh, *, scheme="mixed_v3",
                     method: str = "pipelined", tol: float = 1e-12,
                     maxiter: int = 20_000, block_rows: int = 256,
                     col_tile: int = 512, comm: str = "auto",
                     part: Optional[PartitionedMatrix] = None) -> DistCG:
    """Build a shard_map JPCG over ``mesh`` (all axes flattened to rows).

    ``comm``: how the SpMV assembles its x-window —
      * "allgather" — gather the full vector (general matrices);
      * "halo" — two neighbor ``ppermute``s of ``halo_pad`` entries
        (stencil matrices: bytes drop from (S−1)/S·n to 2·halo per
        device — ~500× for the 1M-row Poisson class);
      * "auto" — halo when the partition supports it and the halo is
        < ¼ of the shard, else allgather.
    """
    scheme = get_scheme(scheme)
    vd = scheme.vector_dtype
    n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    if part is None:
        part = partition_rows(a, n_shards, block_rows=block_rows,
                              col_tile=col_tile)
    n = part.shape[0]
    rows_local = part.rows_per_shard
    n_pad = part.padded_cols
    axes = tuple(mesh.axis_names)

    if comm == "auto":
        comm = ("halo" if part.supports_halo
                and part.halo_pad * 4 <= rows_local else "allgather")
    use_halo = comm == "halo"
    if use_halo and not part.supports_halo:
        raise ValueError("partition does not support halo exchange "
                         f"(halo={part.halo_width}, R={rows_local})")
    halo_pad = part.halo_pad if use_halo else 0
    win_pad = rows_local + 2 * halo_pad        # x-window length (halo)

    shard_spec = P(axes)                       # leading shard axis
    vec_spec = P(axes)                         # row-sharded vectors
    rep = P()

    def _perm(shift):
        return [(i, i + shift) for i in range(n_shards)
                if 0 <= i + shift < n_shards]

    def spmv(shard_args, p_local):
        if use_halo:
            # one-hop halo exchange: left tail -> right neighbor, right
            # head -> left neighbor; edge shards receive zeros (ppermute
            # semantics), matching the absent boundary columns.
            left = jax.lax.ppermute(p_local[-halo_pad:], axes, _perm(1))
            right = jax.lax.ppermute(p_local[:halo_pad], axes, _perm(-1))
            window = jnp.concatenate([left, p_local, right])
            y = _local_spmv(shard_args,
                            window.astype(scheme.spmv_in_dtype),
                            block_rows=part.block_rows,
                            col_tile=part.col_tile, scheme=scheme,
                            n_pad=win_pad)
            return y[:rows_local].astype(vd)
        p_full = jax.lax.all_gather(p_local, axes, tiled=True)
        y = _local_spmv(shard_args, p_full.astype(scheme.spmv_in_dtype),
                        block_rows=part.block_rows, col_tile=part.col_tile,
                        scheme=scheme, n_pad=n_pad)
        return y[:rows_local].astype(vd)

    def pdot(u, v):
        return jax.lax.psum(jnp.dot(u, v), axes)

    # ---------------- paper-faithful (two reductions) ----------------
    def solve_vsr(shard_args, b_l, x_l, d_l):
        r = b_l - spmv(shard_args, x_l)
        z = r / d_l
        p = z
        rz = pdot(r, z)
        rr = pdot(r, r)
        st = (jnp.zeros((), jnp.int32), x_l, r, p, rz, rr)

        def cond(s):
            return (s[0] < maxiter) & (s[5] > tol)

        def body(s):
            i, x, r, p, rz, rr = s
            ap = spmv(shard_args, p)
            alpha = rz / pdot(p, ap)                 # reduction 1
            r2 = r - alpha * ap
            z = r2 / d_l
            packed = jnp.stack([jnp.dot(r2, r2), jnp.dot(r2, z)])
            packed = jax.lax.psum(packed, axes)      # reduction 2 (fused rr+rz)
            rr2, rz2 = packed[0], packed[1]
            beta = rz2 / rz
            return (i + 1, x + alpha * p, r2, z + beta * p, rz2, rr2)

        i, x, r, p, rz, rr = jax.lax.while_loop(cond, body, st)
        return x, i, rr

    # ---------------- pipelined (one reduction) -----------------------
    def solve_pipe(shard_args, b_l, x_l, d_l):
        r = b_l - spmv(shard_args, x_l)
        u = r / d_l
        w = spmv(shard_args, u)
        g0 = jax.lax.psum(
            jnp.stack([jnp.dot(r, u), jnp.dot(w, u), jnp.dot(r, r)]), axes)
        zero = jnp.zeros_like(r)
        one = jnp.ones((), vd)
        st = (jnp.zeros((), jnp.int32), x_l, r, u, w, zero, zero, zero,
              zero, g0[0], one, g0[1], one, g0[2])

        def cond(s):
            return (s[0] < maxiter) & (s[13] > tol)

        def body(s):
            (i, x, r, u, w, z, q, sv, p, gamma, gamma_prev, delta,
             alpha_prev, rr) = s
            m = w / d_l                          # M⁻¹ w
            nvec = spmv(shard_args, m)           # overlaps the psum below
            first = i == 0
            beta = jnp.where(first, jnp.zeros((), vd), gamma / gamma_prev)
            denom = delta - beta * gamma / jnp.where(first, one, alpha_prev)
            alpha = gamma / jnp.where(first, delta, denom)
            z2 = nvec + beta * z
            q2 = m + beta * q
            s2 = w + beta * sv
            p2 = u + beta * p
            x2 = x + alpha * p2
            r2 = r - alpha * s2
            u2 = u - alpha * q2
            w2 = w - alpha * z2
            g = jax.lax.psum(jnp.stack([jnp.dot(r2, u2), jnp.dot(w2, u2),
                                        jnp.dot(r2, r2)]), axes)  # THE psum
            return (i + 1, x2, r2, u2, w2, z2, q2, s2, p2,
                    g[0], gamma, g[1], alpha, g[2])

        out = jax.lax.while_loop(cond, body, st)
        return out[1], out[0], out[13]

    kern = solve_pipe if method == "pipelined" else solve_vsr
    shard_in = (shard_spec,) * 4

    mapped = _shard_map(
        kern, mesh=mesh,
        in_specs=(shard_in, vec_spec, vec_spec, vec_spec),
        out_specs=(vec_spec, rep, rep))

    n_rows_pad = part.padded_rows

    def _pad(v, fill):
        out = jnp.full(n_rows_pad, fill, vd)
        return out.at[: v.shape[0]].set(v.astype(vd))

    tile_cols_host = part.tile_cols_halo() if use_halo else part.tile_cols

    @jax.jit
    def solve(b, x0, diag):
        """b/x0/diag: global vectors of length n (padded here; diag pads
        with 1 so the padded rows solve the identity — no NaNs)."""
        shard_args = (jnp.asarray(tile_cols_host),
                      jnp.asarray(part.vals).astype(scheme.matrix_dtype),
                      jnp.asarray(part.local_rows),
                      jnp.asarray(part.local_cols))
        x, i, rr = mapped(shard_args, _pad(b, 0.0), _pad(x0, 0.0),
                          _pad(diag, 1.0))
        return x[:n], i, rr

    return DistCG(mesh=mesh, part=part, scheme=scheme, method=method,
                  solve=solve)
