"""Activation sharding hints — mesh-context for model-internal constraints.

The models are mesh-agnostic; launchers activate a mesh context and the
layers drop `hint(x, DATA, None, MODEL, None)` constraints at the few
points where GSPMD's propagation otherwise picks a catastrophic layout
(observed: sharding the *head_dim* contraction of attention, which turns
every layer's score matrix into a 5.5 GB all-reduce — see EXPERIMENTS.md
§Perf).  Without an active context every hint is a no-op, so tests and
single-device runs never pay for it.

``DATA`` resolves to ("pod", "data") ∩ mesh axes; ``MODEL`` to "model".
Axis entries that don't exist in the mesh are dropped; uneven dims are
allowed (GSPMD pads internal shardings — e.g. 40 heads on 16 shards).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DATA", "MODEL", "sharding_hints", "hint", "active_mesh"]


class _Axis:
    def __init__(self, name):
        self.name = name

    def __repr__(self):
        return f"<{self.name}>"


DATA = _Axis("DATA")
MODEL = _Axis("MODEL")

_state = threading.local()


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def sharding_hints(mesh: Optional[Mesh]):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def _resolve(entry, mesh: Mesh):
    if entry is DATA:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    if entry is MODEL:
        return "model" if "model" in mesh.axis_names else None
    return entry


def hint(x: jax.Array, *spec) -> jax.Array:
    """Constrain ``x`` to ``spec`` under the active mesh (no-op without)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    resolved = tuple(_resolve(e, mesh) for e in spec)
    if all(e is None for e in resolved):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
