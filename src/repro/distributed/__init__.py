"""Distribution layer: sharding rules (FSDP×TP×EP×DP), activation hints,
distributed CG."""
from repro.distributed import hints
from repro.distributed.cg_dist import DistCG, make_dist_solver
from repro.distributed.hints import DATA, MODEL, hint, sharding_hints
from repro.distributed.sharding import (activation_spec, batch_specs,
                                        cache_specs, data_axes,
                                        named_shardings, param_specs)

__all__ = ["DistCG", "make_dist_solver", "param_specs", "batch_specs",
           "cache_specs", "data_axes", "named_shardings", "activation_spec",
           "hints", "hint", "sharding_hints", "DATA", "MODEL"]
