"""Sharding rules — FSDP(data) × TP(model) × EP(experts→model) × DP(pod).

One rule engine covers every assigned architecture.  Conventions:

* **TP (model axis)**: attention/ssm projection *output* features, MLP
  hidden ``d_ff``, MoE expert axis, vocab dim of the embedding.
* **FSDP (data axis)**: the projection *input* dim (ZeRO-3 style — with
  scan-over-layers GSPMD all-gathers one layer's weights at a time).
* **DP (pod axis)**: batch only.  The pod axis is DCN-attached; placing
  only the gradient all-reduce and CG dot reductions there keeps
  layer-wise collectives intra-pod (DESIGN.md §5).
* Uneven dims (whisper's 51 865 vocab, 40 heads on 16-way TP) rely on
  GSPMD's implicit padding — legal and compile-verified by the dry-run.

Rules are *name- and rank-based* over the param tree paths that
``repro.models`` produces; anything unmatched replicates (norms, scalars).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "data_axes",
           "named_shardings", "activation_spec"]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-parallel axes: ('pod', 'data') on multi-pod, ('data',) else."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return tuple(out)


def _rule(names: Tuple[str, ...], ndim: int) -> P:
    js = "/".join(names)
    leaf = names[-1] if names else ""

    # ---- embeddings: vocab on model (biggest single tensor) ----
    if "embed" in js:
        return P("model", None)

    # ---- MoE expert-stacked weights [L, E, D, F] / [L, E, F, D] ----
    if ndim == 4:
        if leaf == "wo":
            return P(None, "model", None, "data")
        return P(None, "model", "data", None)    # wi / wg
    if "router" in js:
        return P(None, None, None) if ndim == 3 else P(None, None)

    # ---- projection kernels ----
    in_proj = ("wq", "wk", "wv", "wi", "wg", "in_proj")
    out_proj = ("wo", "out_proj")
    parent = names[-2] if len(names) >= 2 else ""
    if leaf == "w" and parent in in_proj:
        return P(None, "data", "model") if ndim == 3 else P("data", "model")
    if leaf == "w" and parent in out_proj:
        return P(None, "model", "data") if ndim == 3 else P("model", "data")
    if leaf == "b" and parent in in_proj + out_proj:
        return P(None, "model") if ndim == 2 else P("model")

    # ---- SSM extras ----
    if leaf == "conv_w":
        return P(None, None, "model") if ndim == 3 else P(None, "model")
    if leaf == "conv_b":
        return P(None, "model") if ndim == 2 else P("model")
    if leaf in ("A_log", "D", "dt_bias"):
        return P(None, "model") if ndim == 2 else P("model")

    # ---- norms / everything else: replicated ----
    return P(*([None] * ndim))


def _fit(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop spec axes whose mesh-axis product does not divide the dim —
    explicit jit in/out shardings (unlike internal constraints) require
    exact divisibility, so e.g. whisper's 51 865 vocab replicates."""
    if mesh is None:
        return spec
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def param_specs(params_or_shapes, mesh: Optional[Mesh] = None):
    """PartitionSpec pytree mirroring a params pytree (arrays or
    ShapeDtypeStructs).  With ``mesh``, specs are divisibility-fitted."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _fit(
            _rule(_path_names(path), len(leaf.shape)), leaf.shape, mesh),
        params_or_shapes)


def batch_specs(batch, mesh: Mesh):
    """Specs for a train/prefill batch dict: batch dim over (pod, data)."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return _fit(P(dp, *([None] * (nd - 1))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_specs(cache, mesh: Mesh, *, batch: int):
    """Decode-cache specs.

    batch ≥ |data|  → batch on data, cache length on model;
    batch 1 (long_500k) → cache length over (data × model), heads/channels
    on model where present.
    """
    dp = data_axes(mesh)
    dsize = 1
    for a in data_axes(mesh):
        dsize *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)
    big_batch = batch % dsize == 0 and batch >= dsize

    def spec(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        if nd == 5 and "ssm" in names:   # SSD state [L, B, H, P, N]
            h_ok = leaf.shape[2] % msize == 0
            return P(None, dp if big_batch else None,
                     "model" if h_ok else None, None, None)
        if nd == 5 and "cross" in names:  # enc-dec cross KV [L, B, T, H, D]
            return P(None, dp if big_batch else None, None, None, None)
        if nd == 5:                      # stacked KV, head-major:
            if big_batch:                # [L, B, H, S, D]
                seq_ok = leaf.shape[3] % msize == 0
                return P(None, dp, None, "model" if seq_ok else None, None)
            seq_ok = leaf.shape[3] % (dsize * msize) == 0
            return P(None, None, None,
                     ("data", "model") if seq_ok else None, None)
        if nd == 4:                      # conv taps [L, B, K-1, C]
            c_ok = leaf.shape[3] % msize == 0
            return P(None, dp if big_batch else None, None,
                     "model" if c_ok else None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _fit(spec(path, leaf), leaf.shape, mesh), cache)


def activation_spec(mesh: Mesh, seq_len: int, *,
                    seq_parallel_above: int = 8192) -> P:
    """Block-boundary activation constraint [B, S, D].

    Long sequences shard S on the model axis between blocks (sequence
    parallelism); short sequences keep S replicated (pure TP inside).
    """
    dp = data_axes(mesh)
    msize = mesh.shape.get("model", 1)
    if seq_len >= seq_parallel_above and seq_len % msize == 0:
        return P(dp, "model", None)
    return P(dp, None, None)


def named_shardings(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
