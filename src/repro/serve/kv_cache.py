"""KV/state-cache accounting + per-slot views.

The cache *structures* live with the models (``AttnCache``/``SSMCache``,
``models.api.init_cache``); this module adds what the serving layer needs:

* byte accounting per request slot (capacity planning / roofline inputs —
  decode is memory-bound on exactly these bytes);
* single-slot extract/insert (every cache leaf carries batch on axis 1,
  so one rule serves attention, SSM, hybrid and enc-dec caches) — used by
  the engine to prefill one request without touching live slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.api import init_cache
from repro.models.config import ModelConfig

__all__ = ["cache_bytes", "bytes_per_slot", "slot_view", "slot_insert",
           "init_cache"]


def cache_bytes(cache) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(cache))


def bytes_per_slot(cfg: ModelConfig, max_len: int,
                   dtype=jnp.bfloat16) -> int:
    """Cache bytes one request slot holds at context ``max_len``."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, 1, max_len, dtype))
    return sum(int(jnp.prod(jnp.asarray(l.shape))) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(shapes))


def slot_view(cache, slot: int):
    """Extract a batch=1 view of request ``slot`` (batch is axis 1)."""
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)


def slot_insert(cache, slot_cache, slot: int):
    """Write a batch=1 slot cache back into the batched cache."""
    return jax.tree_util.tree_map(
        lambda a, u: jax.lax.dynamic_update_slice_in_dim(a, u.astype(a.dtype),
                                                         slot, axis=1),
        cache, slot_cache)
