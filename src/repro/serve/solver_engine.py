"""Slot-based CG solver engine — continuous batching on the stream VM.

The solver twin of :class:`repro.serve.engine.DecodeEngine`, re-plumbed so
the batched stream VM (:mod:`repro.core.vm`) is the one execution backend:
every tick runs one jitted chunked VM step (≤ ``chunk_iters`` executions
of a stream-ISA program) over a fixed pool of problem slots.  Slots are
independent — each carries its own tolerance, iteration budget, and
``active`` flag, so a new system is admitted the moment an old one
converges, without disturbing in-flight lanes (their ``mem`` buffers are
frozen by the VM's masked updates).

Per-request policy and precision
--------------------------------
``submit(..., policy=, scheme=)`` overrides the engine-wide defaults per
request.  Requests are grouped into **pools** keyed by
``(scheme, policy)``; each pool owns ``batch_slots`` slots, its own
bucket, and its own compiled *program* — but programs are runtime
operands, so the compile-cache consequences are deliberately asymmetric:

* a new **scheme** (or a new bucket shape) costs one new VM executable —
  the cache key is :func:`repro.core.compile.executable_key`
  ``(kind, backend, scheme, bucket dims, chunk, steps_per_sync, donate,
  interpret)``;
* a new **policy** costs *nothing*: pools that differ only in policy
  share one jitted stepper and just pass a different ``int32[P, 8]``
  program (all programs are NOP-padded to one canonical length by
  :func:`repro.core.compile.canonical_program`).  This is the paper's
  one-bitstream-serves-any-schedule property, surfaced as an API
  guarantee; ``tests/test_compile.py`` asserts the trace counter stays
  flat across policies.

By default each pool steps through the *specialized* VM path — the
pool's program is unrolled into its stepper at trace time
(``SolverEngineConfig.specialize=False`` restores the traced-operand
stepper, under which policies are free but dispatch is word-at-a-time).
Under specialization a new policy costs one specialized stepper (cached
on the program's bytes via :func:`repro.core.isa.program_token`), and
word-identical programs still share one executable.

Admission (:meth:`SolverEngine.submit`) pads the problem's banked-ELL
arrays into a free slot of the pool's shared bucket shape and runs the
JPCG warm-up (r₀ = b − A·x₀, z₀ = M⁻¹r₀) for that lane only.  The bucket
is sized lazily from the pool's first admitted problem (dimensions
rounded up to power-of-two edges, :func:`repro.sparse.stacking.bucket_up`)
and grows — with one recompile — only when a larger problem arrives.

State-preservation invariants (regression-locked in ``tests``):

* **growth is lossless** — :meth:`_Pool._alloc` copies *all* in-flight
  VM state into the grown arrays: ``mem``, ``sregs``, **and** ``queues``
  (queues used to be silently reset to zeros, which would corrupt any
  program relying on live streams across executions — exactly the
  streams the compiler's live-stream preference creates);
* **frozen lanes are frozen** — the VM masks every state write
  (``mem``/``sregs``/``queues``) on the lane's ``active`` flag, so a
  converged slot's state is bit-stable no matter how many ticks the
  surviving lanes keep running.

Iteration economics (PR 7): each tick donates the pool's state into the
jitted stepper (``cfg.donate``, default on — so :meth:`_Pool.harvest`
materializes results to host before the buffers are consumed), runs
``steps_per_sync`` VM iterations per device round-trip inside the
chunk, and — when the occupied fraction drops below
``cfg.compact_fraction`` at a step boundary — repacks live lanes into
the smallest power-of-two lane bucket (:meth:`_Pool.maybe_compact`) so
converged lanes stop costing arithmetic.  Admission grows the lane
bucket back on demand.

>>> eng = SolverEngine(SolverEngineConfig(batch_slots=8, block_rows=8,
...                                       col_tile=128))
>>> rid = eng.submit(a, tol=1e-12)                      # paper policy
>>> rid2 = eng.submit(a2, policy="min_traffic")         # same executable
>>> done = eng.run_to_completion()                      # {rid: CGResult}
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import (_as_csr, batch_cache_info,
                              batched_matvec_rowell, batched_matvec_sell,
                              batched_matvec_ellpack)
from repro.core.cg import CGResult
from repro.core.compile import canonical_program
from repro.core.isa import BUF, SREG
from repro.core.metrics import (Metrics, initial_status, is_breakdown,
                                is_breakdown_codes, status_name,
                                STATUS_MAXITER, STATUS_RUNNING)
from repro.core.precision import get_scheme
from repro.core.vm import BatchedVMState, make_vm_stepper
from repro.sparse.csr import CSRMatrix
from repro.sparse.ellpack import csr_to_ellpack
from repro.core.shard import mesh_shards
from repro.sparse.stacking import (SELL_SLICE_ROWS, _sell_groups, bucket_up,
                                   choose_layout, csr_rowell, index_dtype,
                                   lane_bucket_up, pad_ellpack,
                                   sell_slice_widths, stack_sell)

__all__ = ["SolverEngineConfig", "SolverEngine"]


@dataclasses.dataclass(frozen=True)
class SolverEngineConfig:
    batch_slots: int = 8              # slots per (scheme, policy) pool
    scheme: str = "mixed_v3"          # default; per-request override
    policy: str = "paper"             # default VSR policy; per-request
    tol: float = 1e-12                # default; per-request override
    maxiter: int = 20_000             # default; per-request override
    chunk_iters: int = 64             # iterations per tick
    block_rows: int = 256
    col_tile: int = 512
    backend: str = "xla"              # "xla" | "pallas"
    layout: str = "auto"              # "auto" | "rowell" | "sell" (xla)
    #                                   "auto" | "ellpack" | "sell" (pallas);
    #                                   auto resolves per pool at first admit
    #                                   via the padding-ratio heuristic
    interpret: Optional[bool] = None  # pallas backend: None = auto
    specialize: bool = True           # program-specialized steppers
    steps_per_sync: int = 8           # VM ticks per termination sync
    donate: bool = True               # donate state into each step
    compact_fraction: float = 0.5     # repack lanes when live/lanes < this
    detect: bool = True               # in-loop breakdown detection
    escalate_fp64: bool = False       # retry a breakdown once at fp64
    escalate_scheme: str = "fp64"     # where escalation re-routes to
    mesh: Optional[object] = None     # jax.sharding.Mesh over the lane
    #                                   axis (repro.core.shard.lane_mesh);
    #                                   None = single-device pools


@partial(jax.jit, static_argnames=("scheme",))
def _lane_init_rowell(cols, vals, diag, b, x0, *, scheme):
    """JPCG warm-up for one lane (Alg. 1 lines 1–5, batch-of-one view)."""
    y = batched_matvec_rowell(cols[None], vals[None], x0[None],
                              scheme=scheme)[0]
    r = b - y
    z = r / diag
    return r, z, jnp.dot(r, z), jnp.dot(r, r)


@partial(jax.jit, static_argnames=("groups", "scheme"))
def _lane_init_sell(cols, vals, iperm, diag, b, x0, *, groups, scheme):
    """JPCG warm-up for one SELL-packed lane.  Used by both backends:
    the Pallas sell SpMV reduces through the same halving tree, so the
    XLA spelling is bit-identical and saves one kernel variant here."""
    y = batched_matvec_sell(cols[None], vals[None], iperm[None], x0[None],
                            groups=groups, scheme=scheme)[0]
    r = b - y
    z = r / diag
    return r, z, jnp.dot(r, z), jnp.dot(r, r)


@partial(jax.jit, static_argnames=("col_tile", "n_col_tiles", "scheme",
                                   "interpret"))
def _lane_init_ell(tc, v, lc, diag, b, x0, *, col_tile, n_col_tiles,
                   scheme, interpret):
    y = batched_matvec_ellpack(tc[None], v[None], lc[None], x0[None],
                               col_tile=col_tile, n_col_tiles=n_col_tiles,
                               scheme=scheme, interpret=interpret)[0]
    r = b - y
    z = r / diag
    return r, z, jnp.dot(r, z), jnp.dot(r, r)


class _Pool:
    """Slots + VM state for one (scheme, policy) request class."""

    def __init__(self, cfg: SolverEngineConfig, scheme, policy: str,
                 interpret: bool, metrics: Optional[Metrics] = None):
        self.cfg = cfg
        self.scheme = scheme
        self.policy = policy
        self.interpret = interpret
        self.metrics = metrics if metrics is not None else Metrics()
        self.program_np = np.asarray(canonical_program(policy), np.int32)
        self.program = jnp.asarray(self.program_np)
        self.mesh = cfg.mesh
        self.n_dev = mesh_shards(cfg.mesh)
        # Lane capacity: with a mesh the lane axis must stay divisible
        # by the shard count (NamedSharding), so the cap and every
        # resize round through lane_bucket_up (device-count-aware).
        self.capacity = (cfg.batch_slots if self.mesh is None
                         else lane_bucket_up(cfg.batch_slots,
                                             parts=self.n_dev))
        self.slots = self.capacity               # current lane capacity
        self.req_of_slot: list = [None] * self.slots   # request id or None
        self.n_of_slot = np.zeros(self.slots, np.int64)  # logical n per slot
        self.csr_of_slot: list = [None] * self.slots  # kept for sell rebuild
        self.bucket = None                       # per-backend dims tuple
        self.mat = None                          # slot-stacked arrays
        self.state: Optional[BatchedVMState] = None
        self.tol = None
        self.maxiter_vec = None
        # Matrix layout, resolved at first admit ("auto" applies the
        # padding-ratio heuristic to the first admitted system).
        self.layout = None if cfg.layout == "auto" else cfg.layout
        self.sell_widths = None                  # per-slice widths (sell)
        self.groups = None                       # static (rows, w) runs

    # ------------------------------------------------------------ sizing
    def _dims_of(self, m):
        """Pallas bucket signature: (row blocks, slabs, ell, col tiles).

        The XLA backend's row-ELL dims — ``(padded rows, row width)`` —
        come straight from the CSR in :meth:`admit`.
        """
        return (m.n_row_blocks, m.n_slabs, m.ell, m.n_col_tiles)

    def _lane_round(self, want: int) -> int:
        """Next lane-bucket edge — shard-divisible under a mesh."""
        return (bucket_up(want) if self.mesh is None
                else lane_bucket_up(want, parts=self.n_dev))

    def _n_pad(self, dims):
        if self.layout == "sell" or self.cfg.backend == "xla":
            return dims[0]
        return dims[0] * self.cfg.block_rows

    def _alloc(self, dims):
        """(Re)allocate the slot-stacked arrays for bucket ``dims`` at the
        current lane capacity ``self.slots``, copying any in-flight lanes.

        Serves three resize paths with one copy-and-pad: first admission,
        bucket growth (a larger problem arrives), and lane growth
        (admission after converged-lane compaction shrank the pool).

        Matrix operands per layout: row-ELL grows in place (the old
        slot-major region stays valid at any padded size because pad
        columns are row-own ids); sliced-ELL *rebuilds* every lane from
        the retained per-slot CSRs — the shared slice widths re-shuffle
        the flat slot offsets, so an in-place copy has no meaning.  VM
        *state* is layout-independent (original row order) and is always
        copied forward.
        """
        S = self.slots
        vd = self.scheme.vector_dtype
        md = self.scheme.matrix_dtype
        n_pad = self._n_pad(dims)
        old_mat, old_state = self.mat, self.state
        if len(self.req_of_slot) < S:
            pad_n = S - len(self.req_of_slot)
            self.req_of_slot += [None] * pad_n
            self.csr_of_slot += [None] * pad_n
            self.n_of_slot = np.pad(self.n_of_slot, (0, pad_n))

        if self.layout == "sell":
            # Full rebuild at the pool's shared geometry; empty slots get
            # a zero-nnz placeholder (self-gathering pad entries only).
            empty = CSRMatrix(np.zeros(2, np.int64), np.zeros(0, np.int32),
                              np.zeros(0, np.float64), (1, 1))
            stacked = stack_sell(
                [c if c is not None else empty for c in self.csr_of_slot],
                n_pad=n_pad, widths=self.sell_widths, scheme=self.scheme)
            self.groups = stacked.groups
            mat = (jnp.asarray(stacked.cols), jnp.asarray(stacked.vals),
                   jnp.asarray(stacked.iperm))
        elif self.cfg.backend == "xla":
            N, W = dims
            idt = index_dtype(N)
            # padding entries are (col i, val 0) for row i: self-gather,
            # so no lane can be poisoned through another row's x entry
            cols = jnp.broadcast_to(jnp.arange(N, dtype=idt), (S, W, N))
            mat = (cols, jnp.zeros((S, W, N), md))
        else:
            B, T, L, _ = dims
            R = self.cfg.block_rows
            mat = (jnp.zeros((S, B, T), jnp.int32),
                   jnp.zeros((S, B, T, L, R), md),
                   jnp.zeros((S, B, T, L, R), jnp.int32))
        mem = jnp.zeros((6, S, n_pad), vd)
        mem = mem.at[BUF["M"]].set(1.0)          # unit diag on empty rows
        state = BatchedVMState(
            k=jnp.zeros((), jnp.int32), it=jnp.zeros(S, jnp.int32),
            status=jnp.zeros(S, jnp.int32),
            mem=mem, queues=jnp.zeros((8, S, n_pad), vd),
            sregs=jnp.zeros((6, S), vd), active=jnp.zeros(S, bool),
            trace=jnp.zeros((S, 0), vd))
        tol = jnp.full(S, self.cfg.tol, vd)
        maxiter_vec = jnp.zeros(S, jnp.int32)

        if old_mat is not None:
            # Growing bucket and/or lane count: copy every old lane into
            # the new arrays — mem, sregs AND queues (live streams must
            # survive growth; padded tails stay zero, which is what a
            # wider VM would hold for rows that never existed).  New
            # lanes keep the fresh-alloc empty-lane state (unit diag).
            def grow(new, old):
                pads = [(0, n - o) for n, o in zip(new.shape, old.shape)]
                return jnp.pad(old, pads)
            if self.layout == "sell":
                pass            # mat fully rebuilt from the slot CSRs
            elif self.cfg.backend == "xla":
                # old slot-major [S0, W0, N0] region is valid verbatim;
                # .set also casts int16 cols up if N crossed 2^15
                mat = tuple(
                    new.at[tuple(slice(0, d) for d in old.shape)]
                    .set(old.astype(new.dtype))
                    for new, old in zip(mat, old_mat))
            else:
                mat = tuple(grow(n, o) for n, o in zip(mat, old_mat))
            S_old = old_state.mem.shape[1]
            old_n = old_state.mem.shape[-1]
            mem = mem.at[:, :S_old, :old_n].set(old_state.mem)
            queues = state.queues.at[:, :S_old, :old_n].set(
                old_state.queues)
            state = state._replace(
                k=old_state.k, it=grow(state.it, old_state.it), mem=mem,
                queues=queues, sregs=grow(state.sregs, old_state.sregs),
                active=grow(state.active, old_state.active),
                status=grow(state.status, old_state.status))
            tol = tol.at[:S_old].set(self.tol)
            maxiter_vec = maxiter_vec.at[:S_old].set(self.maxiter_vec)
            self.metrics.bump("growths")
        self.bucket = dims
        self.mat = mat
        self.state = state
        self.tol = tol
        self.maxiter_vec = maxiter_vec

    # ---------------------------------------------------------- admission
    def admit(self, a, b, x0, tol, maxiter) -> int:
        """Place one system into a free slot; returns the slot index."""
        free = [s for s, r in enumerate(self.req_of_slot) if r is None]
        if not free and self.slots < self.capacity:
            # Compaction shrank the pool; grow lanes back for this admit.
            self.slots = min(self.capacity, self._lane_round(self.slots + 1))
            self._alloc(self.bucket)
            free = [s for s, r in enumerate(self.req_of_slot) if r is None]
        if not free:
            raise RuntimeError(
                f"no free solver slots in pool "
                f"(scheme={self.scheme.name}, policy={self.policy})")
        s = free[0]
        cfg = self.cfg
        a = _as_csr(a)
        if self.layout is None:
            self.layout = choose_layout(
                [a], default="rowell" if cfg.backend == "xla" else "ellpack")
        if self.layout == "sell":
            n_pad = bucket_up(a.shape[0])
            if self.bucket is not None:
                n_pad = max(n_pad, self.bucket[0])
            stored = [c for c in self.csr_of_slot if c is not None]
            wnew = sell_slice_widths(stored + [a], n_pad=n_pad)
            if self.sell_widths is not None:
                # n_pad growth appends zero-nnz rows, which a global sort
                # sends to the tail: old slice widths stay valid for the
                # leading slices, so the merge is a zero-padded max —
                # widths only ever grow (bucket-signature stability).
                old = self.sell_widths + (0,) * (len(wnew) -
                                                 len(self.sell_widths))
                wnew = tuple(max(o, w) for o, w in zip(old, wnew))
            self.csr_of_slot[s] = a
            if (self.bucket is None or n_pad != self.bucket[0]
                    or wnew != self.sell_widths):
                self.sell_widths = wnew
                groups = _sell_groups(wnew, n_pad=n_pad,
                                      slice_rows=max(1, min(SELL_SLICE_ROWS,
                                                            n_pad)))
                self._alloc((n_pad,) + tuple(
                    d for rw in groups for d in rw))
            else:
                st1 = stack_sell([a], n_pad=n_pad, widths=self.sell_widths,
                                 scheme=self.scheme)
                lanes = (st1.cols[0], st1.vals[0], st1.iperm[0])
                self.mat = tuple(
                    arr.at[s].set(jnp.asarray(lane).astype(arr.dtype))
                    for arr, lane in zip(self.mat, lanes))
        else:
            if cfg.backend == "xla":
                cols_l, vals_l = csr_rowell(a)
                dims = (bucket_up(a.shape[0]), bucket_up(cols_l.shape[1]))
            else:
                m = csr_to_ellpack(a, block_rows=cfg.block_rows,
                                   col_tile=cfg.col_tile)
                dims = tuple(bucket_up(d) for d in self._dims_of(m))
            if self.bucket is None or any(d > o for d, o in
                                          zip(dims, self.bucket)):
                grown = dims if self.bucket is None else tuple(
                    max(d, o) for d, o in zip(dims, self.bucket))
                self._alloc(grown)
            if cfg.backend == "xla":
                # slot-major lane slab over the whole bucket: self-gather
                # template, then the real entries transposed in
                N, W = self.bucket
                n, w_a = cols_l.shape
                idt = index_dtype(N)
                lane_cols = np.broadcast_to(np.arange(N, dtype=idt),
                                            (W, N)).copy()
                lane_cols[:w_a, :n] = cols_l.T
                lane_vals = np.zeros((W, N), self.scheme.matrix_dtype)
                lane_vals[:w_a, :n] = vals_l.T
                lanes = (lane_cols, lane_vals)
            else:
                B, T, L, _ = self.bucket
                m = pad_ellpack(m, n_row_blocks=B, n_slabs=T, ell=L)
                lanes = (m.tile_cols, m.vals, m.local_cols)
            self.csr_of_slot[s] = a
            self.mat = tuple(
                arr.at[s].set(jnp.asarray(lane).astype(arr.dtype))
                for arr, lane in zip(self.mat, lanes))

        vd = self.scheme.vector_dtype
        n = a.shape[0]
        n_pad = self.state.mem.shape[-1]
        d = np.ones(n_pad)
        d[:n] = a.diagonal()
        bb = np.zeros(n_pad)
        bb[:n] = np.ones(n) if b is None else np.asarray(b)
        xx = np.zeros(n_pad)
        if x0 is not None:
            xx[:n] = np.asarray(x0)
        diag_l = jnp.asarray(d, vd)
        b_l = jnp.asarray(bb, vd)
        x0_l = jnp.asarray(xx, vd)

        if self.layout == "sell":
            lc, lv, lip = (arr[s] for arr in self.mat)
            r, z, rz, rr = _lane_init_sell(
                lc, lv, lip, diag_l, b_l, x0_l, groups=self.groups,
                scheme=self.scheme)
        elif cfg.backend == "xla":
            gc, v = (arr[s] for arr in self.mat)
            r, z, rz, rr = _lane_init_rowell(
                gc, v, diag_l, b_l, x0_l, scheme=self.scheme)
        else:
            tc, v, lc = (arr[s] for arr in self.mat)
            r, z, rz, rr = _lane_init_ell(
                tc, v, lc, diag_l, b_l, x0_l, col_tile=cfg.col_tile,
                n_col_tiles=self.bucket[-1], scheme=self.scheme,
                interpret=self.interpret)

        st = self.state
        lane_mem = jnp.stack([x0_l, r, z, jnp.zeros_like(r), diag_l, b_l])
        req_tol = jnp.asarray(cfg.tol if tol is None else tol, vd)
        sregs = st.sregs.at[:, s].set(0.0)
        sregs = sregs.at[SREG["rz"], s].set(rz)
        sregs = sregs.at[SREG["rr"], s].set(rr)
        self.state = st._replace(
            it=st.it.at[s].set(0), mem=st.mem.at[:, s].set(lane_mem),
            queues=st.queues.at[:, s].set(0.0), sregs=sregs,
            active=st.active.at[s].set(rr > req_tol),
            status=st.status.at[s].set(
                initial_status(rr, req_tol, detect=cfg.detect)))
        self.tol = self.tol.at[s].set(req_tol)
        self.maxiter_vec = self.maxiter_vec.at[s].set(
            cfg.maxiter if maxiter is None else maxiter)
        self.n_of_slot[s] = n
        self.metrics.bump("admits")
        self.metrics.bump("spmv_calls")          # the warm-up r0 = b - A·x0
        self.metrics.bump("bytes_streamed_est", self._lane_stream_bytes())
        return s

    def _lane_stream_bytes(self) -> int:
        """At-rest nonzero stream per lane per SpMV: packed values +
        column indices, padding included — i.e.
        ``scheme.nonzero_stream_bytes(index_bytes) × padding_ratio × nnz``
        computed directly from the slot-stacked arrays."""
        ellpack = self.cfg.backend == "pallas" and self.layout != "sell"
        if ellpack:
            nb = self.mat[1].nbytes + self.mat[2].nbytes
        else:
            nb = self.mat[0].nbytes + self.mat[1].nbytes
        return int(nb) // self.slots

    # -------------------------------------------------------------- tick
    @property
    def any_active(self) -> bool:
        return self.state is not None and bool(self.state.active.any())

    def step(self) -> None:
        cfg = self.cfg
        ellpack = cfg.backend == "pallas" and self.layout != "sell"
        index_bytes = int(self.mat[2 if ellpack else 0].dtype.itemsize)
        stepper_kw = dict(
            backend=cfg.backend, scheme=self.scheme, bucket=self.bucket,
            chunk=cfg.chunk_iters, layout=self.layout, groups=self.groups,
            index_bytes=index_bytes, block_rows=cfg.block_rows,
            col_tile=cfg.col_tile,
            n_col_tiles=self.bucket[-1] if ellpack else None,
            steps_per_sync=cfg.steps_per_sync, donate=cfg.donate,
            detect=cfg.detect, interpret=self.interpret, mesh=self.mesh)
        # Materialize the pre-step counters to host before the call —
        # with cfg.donate the state operand is consumed by the stepper.
        it0 = np.asarray(self.state.it)
        st0 = np.asarray(self.state.status)
        if cfg.specialize:
            stepper = make_vm_stepper(program=self.program_np, **stepper_kw)
            self.state = stepper(self.mat, self.state, self.tol,
                                 self.maxiter_vec)
        else:
            stepper = make_vm_stepper(**stepper_kw)
            self.state = stepper(self.program, self.mat, self.state,
                                 self.tol, self.maxiter_vec)
        # Accounting: committed iterations plus one discarded program
        # execution per lane that broke down during this step (its tick
        # ran the SpMV before the writes were thrown away).  Frozen
        # lanes' SIMD dead compute is deliberately NOT counted — it
        # streams nothing on the modeled architecture.
        it_delta = int((np.asarray(self.state.it) - it0).sum())
        broke = int((is_breakdown_codes(np.asarray(self.state.status))
                     & ~is_breakdown_codes(st0)).sum())
        m = self.metrics
        m.bump("chunks")
        m.bump("iterations", it_delta)
        m.bump("spmv_calls", it_delta + broke)
        m.bump("bytes_streamed_est",
               (it_delta + broke) * self._lane_stream_bytes())

    def harvest(self) -> Dict[int, CGResult]:
        if self.state is None:
            return {}
        done: Dict[int, CGResult] = {}
        active = np.asarray(self.state.active)
        its = np.asarray(self.state.it)
        statuses = np.asarray(self.state.status)
        rrs = np.asarray(self.state.sregs[SREG["rr"]])
        tols = np.asarray(self.tol)
        for s, rid in enumerate(self.req_of_slot):
            if rid is None or active[s]:
                continue
            n = int(self.n_of_slot[s])
            # Materialize to host: with cfg.donate the pool's device state
            # is consumed by the next step(), which would invalidate any
            # device view we handed out here.
            x = np.asarray(self.state.mem[BUF["x"], s, :n])
            # An inactive lane still RUNNING is the detection-off
            # non-finite-at-admit corner (it deactivated without ever
            # ticking); it wears the budget-exhausted face.
            code = int(statuses[s])
            if code == STATUS_RUNNING:
                code = STATUS_MAXITER
            done[rid] = CGResult(
                x=x, iterations=int(its[s]),
                rr=float(rrs[s]), converged=bool(rrs[s] <= tols[s]),
                residual_trace=None, scheme=self.scheme.name,
                method=f"vm_engine[{self.policy}]",
                status=status_name(code))
            self.req_of_slot[s] = None
            # release the CSR: a departed lane must not keep inflating
            # future sell width merges (widths stay monotone regardless)
            self.csr_of_slot[s] = None
            self.metrics.bump("harvests")
        return done

    # --------------------------------------------------------- compaction
    def maybe_compact(self) -> bool:
        """Repack live lanes into a smaller lane bucket when most slots
        sit idle.  Runs only at step boundaries (after harvest), when the
        occupied fraction drops strictly below ``cfg.compact_fraction``
        and the occupied count fits a smaller power-of-two lane bucket.
        Every VM op is lane-independent, so repacking is bitwise-neutral
        per lane; it trades one retrace (new lane count) for every
        subsequent tick costing arithmetic proportional to live lanes.
        Returns True if the pool was repacked.

        Under a lane mesh compaction is **device-local**: slot ``s``
        lives on shard ``s // (S/D)``, and live lanes are repacked
        within their own shard only — migrating a live lane would move
        its in-flight VM state across devices mid-solve.  The per-shard
        lane bucket is sized by the fullest shard, so the compacted
        lane count stays shard-divisible."""
        if self.state is None:
            return False
        S = self.slots
        occ = [s for s, r in enumerate(self.req_of_slot) if r is not None]
        live = len(occ)
        if live == 0:
            return False
        D = self.n_dev
        if D <= 1:
            target = bucket_up(live)
            if target >= S or live / S >= self.cfg.compact_fraction:
                return False
            sel = np.asarray(
                occ[:target] +
                [s for s in range(S) if s not in occ][: target - live],
                np.int64)
        else:
            per = S // D
            by_shard = [[s for s in occ if s // per == d] for d in range(D)]
            t_per = bucket_up(max(len(o) for o in by_shard))
            target = t_per * D
            if target >= S or live / S >= self.cfg.compact_fraction:
                return False
            sel_l: list = []
            for d, o in enumerate(by_shard):
                base = d * per
                free = [s for s in range(base, base + per)
                        if self.req_of_slot[s] is None]
                sel_l += (o + free)[:t_per]
            sel = np.asarray(sel_l, np.int64)
        sel_j = jnp.asarray(sel)
        self.mat = tuple(arr[sel_j] for arr in self.mat)
        st = self.state
        self.state = st._replace(
            it=st.it[sel_j], status=st.status[sel_j], mem=st.mem[:, sel_j],
            queues=st.queues[:, sel_j], sregs=st.sregs[:, sel_j],
            active=st.active[sel_j], trace=st.trace[sel_j])
        self.tol = self.tol[sel_j]
        self.maxiter_vec = self.maxiter_vec[sel_j]
        self.req_of_slot = [self.req_of_slot[s] for s in sel]
        self.csr_of_slot = [self.csr_of_slot[s] for s in sel]
        self.n_of_slot = self.n_of_slot[sel]
        self.slots = target
        self.metrics.bump("compactions")
        return True


class SolverEngine:
    """Admit SPD systems into batch slots; solve them on the stream VM."""

    def __init__(self, cfg: SolverEngineConfig):
        self.cfg = cfg
        if cfg.interpret is None:
            from repro.kernels.ops import default_interpret
            self.interpret = default_interpret()
        else:
            self.interpret = cfg.interpret
        self._pools: Dict[Tuple[str, str], _Pool] = {}
        self._next_id = 0
        self.results: Dict[int, CGResult] = {}
        self._metrics = Metrics()
        # Request meta for the escalation policy: rid -> (a, b, x0, tol,
        # maxiter, policy).  Only populated when cfg.escalate_fp64 is on
        # (retaining every operand would defeat slot recycling otherwise).
        self._meta: Dict[int, tuple] = {}
        self._retried: set = set()

    def _pool(self, scheme: Optional[str], policy: Optional[str]) -> _Pool:
        scheme = get_scheme(self.cfg.scheme if scheme is None else scheme)
        policy = self.cfg.policy if policy is None else policy
        key = (scheme.name, policy)
        if key not in self._pools:
            self._pools[key] = _Pool(self.cfg, scheme, policy,
                                     self.interpret, self._metrics)
        return self._pools[key]

    def metrics(self) -> dict:
        """Engine observability snapshot — a plain dict (json-safe).

        Counters: ``admits`` / ``harvests`` / ``escalations`` (request
        lifecycle), ``chunks`` / ``iterations`` / ``spmv_calls`` /
        ``bytes_streamed_est`` (work executed; bytes = SpMV events × the
        per-lane at-rest nonzero stream, padding included), ``growths`` /
        ``compactions`` (pool geometry events); ``exit_status`` is the
        histogram of *recorded* request exits (escalated-and-retried
        requests count once, at their final exit); ``pools`` reports
        per-(scheme, policy) slot occupancy; ``executable_cache`` is
        :func:`repro.core.batch.batch_cache_info`.
        """
        pools = {
            f"{sch}/{pol}": {
                "slots": p.slots,
                "shards": p.n_dev,
                "occupied": sum(r is not None for r in p.req_of_slot),
                "active": (int(p.state.active.sum())
                           if p.state is not None else 0),
            }
            for (sch, pol), p in self._pools.items()}
        return self._metrics.snapshot(extra={
            "pools": pools, "executable_cache": batch_cache_info()})

    # ------------------------------------------------------------ public
    def free_slots(self, pool: Optional[Tuple[Optional[str],
                                              Optional[str]]] = None) -> int:
        """Free solver slots across the whole engine.

        With ``pool=None`` (default) sums the free slots of **every**
        instantiated (scheme, policy) pool — per-request ``scheme=``/
        ``policy=`` overrides create pools lazily, and admission control
        steering on this number must see all of them.  (It used to count
        only the default pool, so callers saw phantom fullness — slots
        free in override pools — and phantom capacity — a full default
        pool reported while overrides were also full.)  Before any pool
        exists it reports ``cfg.batch_slots``, the capacity the first
        submit will materialize.

        ``pool=(scheme, policy)`` restores the single-pool view (``None``
        components fall back to the engine defaults); an uninstantiated
        pool reports its full capacity.
        """
        cap0 = (self.cfg.batch_slots if self.cfg.mesh is None
                else lane_bucket_up(self.cfg.batch_slots,
                                    parts=mesh_shards(self.cfg.mesh)))

        def pool_free(p: Optional[_Pool]) -> int:
            if p is None:
                return cap0
            # Capacity view: lanes a compacted pool currently materializes
            # is an implementation detail — admission grows them back, so
            # free capacity is configured slots minus occupied ones.
            return p.capacity - sum(
                r is not None for r in p.req_of_slot)

        if pool is not None:
            scheme, policy = pool
            key = (get_scheme(self.cfg.scheme if scheme is None
                              else scheme).name,
                   self.cfg.policy if policy is None else policy)
            return pool_free(self._pools.get(key))
        if not self._pools:
            return self.cfg.batch_slots
        return sum(pool_free(p) for p in self._pools.values())

    @property
    def active_count(self) -> int:
        return sum(int(p.state.active.sum()) for p in self._pools.values()
                   if p.state is not None)

    def submit(self, a, b=None, x0=None, *, tol: Optional[float] = None,
               maxiter: Optional[int] = None, policy: Optional[str] = None,
               scheme: Optional[str] = None) -> int:
        """Admit one SPD system; returns the request id.

        ``policy``/``scheme`` override the engine defaults per request and
        route the system to the matching (scheme, policy) pool — see the
        module docstring for what each override costs in executables.

        With ``cfg.escalate_fp64`` the request's operands are retained so
        a breakdown exit can be retried once in the
        ``cfg.escalate_scheme`` pool (the result then carries
        ``retried=True``).
        """
        self._harvest()        # a lane done since the last tick frees its slot
        pool = self._pool(scheme, policy)
        s = pool.admit(a, b, x0, tol, maxiter)
        rid = self._next_id
        self._next_id += 1
        pool.req_of_slot[s] = rid
        if self.cfg.escalate_fp64:
            self._meta[rid] = (a, b, x0, tol, maxiter,
                               self.cfg.policy if policy is None else policy)
        return rid

    def step(self) -> Dict[int, CGResult]:
        """One chunked tick (≤ ``chunk_iters`` iterations for every live
        lane in every pool); harvests and frees slots that finished,
        returning ``{request_id: CGResult}``."""
        for pool in self._pools.values():
            if pool.any_active:
                pool.step()
        done = self._harvest()
        for pool in self._pools.values():
            pool.maybe_compact()
        return done

    def _harvest(self) -> Dict[int, CGResult]:
        raw: Dict[int, CGResult] = {}
        for pool in self._pools.values():
            raw.update(pool.harvest())
        done: Dict[int, CGResult] = {}
        for rid, res in raw.items():
            if self._should_escalate(rid, res):
                # One retry at the escalation scheme: re-admit the
                # retained operands into the target pool under the SAME
                # request id — the caller sees one request, one (final)
                # result, with retried=True.
                a, b, x0, tol, maxiter, policy = self._meta[rid]
                pool = self._pool(self.cfg.escalate_scheme, policy)
                s = pool.admit(a, b, x0, tol, maxiter)
                pool.req_of_slot[s] = rid
                self._retried.add(rid)
                self._metrics.bump("escalations")
                continue
            res.retried = rid in self._retried
            self._metrics.record_exit(res.status)
            self._meta.pop(rid, None)
            self._retried.discard(rid)
            done[rid] = res
        self.results.update(done)
        return done

    def _should_escalate(self, rid: int, res: CGResult) -> bool:
        if not (self.cfg.escalate_fp64 and is_breakdown(res.status)):
            return False
        if rid in self._retried or rid not in self._meta:
            return False
        target = get_scheme(self.cfg.escalate_scheme)
        if res.scheme == target.name:
            return False       # already ran at the escalation scheme
        if (target.vector_dtype == jnp.float64
                and not jax.config.read("jax_enable_x64")):
            return False       # fp64 retry impossible without x64
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> Dict[int, CGResult]:
        """Tick until every admitted system finished; returns all results
        harvested during the call.  Raises if ``max_ticks`` elapses with
        lanes still live (truncation must be observable, not a silently
        missing request id)."""
        out: Dict[int, CGResult] = {}
        out.update(self._harvest())
        ticks = 0
        while any(p.any_active for p in self._pools.values()):
            if ticks >= max_ticks:
                live = [rid for p in self._pools.values()
                        for s, rid in enumerate(p.req_of_slot)
                        if rid is not None and bool(p.state.active[s])]
                raise RuntimeError(
                    f"run_to_completion hit max_ticks={max_ticks} with "
                    f"requests {live} still active (chunk_iters="
                    f"{self.cfg.chunk_iters}); raise max_ticks or maxiter")
            out.update(self.step())
            ticks += 1
        return out
