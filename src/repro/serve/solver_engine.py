"""Slot-based CG solver engine — continuous batching for linear systems.

The solver twin of :class:`repro.serve.engine.DecodeEngine`: a fixed pool
of ``batch_slots`` problem slots iterates in lock-step (one jitted
chunked tick over the whole batch), and slots are independent — each
carries its own tolerance, iteration budget, and ``active`` flag, so a
new system can be admitted the moment an old one converges, without
disturbing in-flight lanes (their state is frozen by the same masked
updates the batched solver uses).

Admission (:meth:`SolverEngine.submit`) pads the problem's banked-ELL
arrays into a free slot of the engine's shared *bucket* shape and runs
the JPCG warm-up (r₀ = b − A·x₀, z₀ = M⁻¹r₀) for that lane only.  The
bucket is sized lazily from the first admitted problem (dimensions
rounded up to power-of-two edges, :func:`repro.sparse.stacking.bucket_up`)
and grows — with one recompile — only when a larger problem arrives, so
steady traffic of similar systems reuses a single executable, exactly
the compile-cache policy of :mod:`repro.core.batch`.

>>> eng = SolverEngine(SolverEngineConfig(batch_slots=8, block_rows=8,
...                                       col_tile=128))
>>> rid = eng.submit(a, tol=1e-12)
>>> done = eng.run_to_completion()          # {rid: CGResult}
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import (BatchedCGState, _as_csr, batched_matvec_flat,
                              batched_matvec_ellpack, make_batched_stepper)
from repro.core.cg import CGResult
from repro.core.precision import get_scheme
from repro.sparse.bell import csr_to_bell
from repro.sparse.ellpack import csr_to_ellpack
from repro.sparse.stacking import bucket_up, flatten_bell, pad_ellpack

__all__ = ["SolverEngineConfig", "SolverEngine"]


@dataclasses.dataclass(frozen=True)
class SolverEngineConfig:
    batch_slots: int = 8
    scheme: str = "mixed_v3"
    tol: float = 1e-12                # default; per-request override
    maxiter: int = 20_000             # default; per-request override
    chunk_iters: int = 64             # iterations per tick
    block_rows: int = 256
    col_tile: int = 512
    backend: str = "xla"              # "xla" | "pallas"
    interpret: Optional[bool] = None  # pallas backend: None = auto


@partial(jax.jit, static_argnames=("n_rows", "padded_cols", "scheme"))
def _lane_init_flat(gc, v, rw, diag, b, x0, *, n_rows, padded_cols, scheme):
    """JPCG warm-up for one lane (Alg. 1 lines 1–5, batch-of-one view)."""
    y = batched_matvec_flat(gc[None], v[None], rw[None], x0[None],
                            n_rows=n_rows, padded_cols=padded_cols,
                            scheme=scheme)[0]
    r = b - y
    z = r / diag
    return r, z, jnp.dot(r, z), jnp.dot(r, r)


@partial(jax.jit, static_argnames=("col_tile", "n_col_tiles", "scheme",
                                   "interpret"))
def _lane_init_ell(tc, v, lc, diag, b, x0, *, col_tile, n_col_tiles,
                   scheme, interpret):
    y = batched_matvec_ellpack(tc[None], v[None], lc[None], x0[None],
                               col_tile=col_tile, n_col_tiles=n_col_tiles,
                               scheme=scheme, interpret=interpret)[0]
    r = b - y
    z = r / diag
    return r, z, jnp.dot(r, z), jnp.dot(r, r)


class SolverEngine:
    """Admit SPD systems into batch slots; solve them in shared ticks."""

    def __init__(self, cfg: SolverEngineConfig):
        self.cfg = cfg
        self.scheme = get_scheme(cfg.scheme)
        if cfg.interpret is None:
            from repro.kernels.ops import default_interpret
            self.interpret = default_interpret()
        else:
            self.interpret = cfg.interpret
        S = cfg.batch_slots
        self._req_of_slot: list = [None] * S     # request id or None
        self._n_of_slot = np.zeros(S, np.int64)  # logical n per slot
        self._next_id = 0
        self._bucket = None                      # (B, T, L, n_tiles)
        self._mat = None                         # stacked device arrays
        self._state: Optional[BatchedCGState] = None
        self._diag = None
        self._tol = None
        self._maxiter_vec = None
        self.results: Dict[int, CGResult] = {}

    # ------------------------------------------------------------ sizing
    def _dims_of(self, m):
        """Bucket signature: (row blocks, stream/slot dims..., col tiles).

        xla uses the flat stream — (blocks, stream length, tiles); pallas
        keeps the slot-major structure — (blocks, slabs, ell, tiles).
        """
        if self.cfg.backend == "xla":
            return (m.n_row_blocks, m.stored_entries, m.n_col_tiles)
        return (m.n_row_blocks, m.n_slabs, m.ell, m.n_col_tiles)

    def _alloc(self, dims):
        """Allocate (or grow) the slot-stacked arrays for bucket ``dims``."""
        S = self.cfg.batch_slots
        B, n_tiles = dims[0], dims[-1]
        vd = self.scheme.vector_dtype
        md = self.scheme.matrix_dtype
        n_pad = B * self.cfg.block_rows
        old_mat, old_state = self._mat, self._state

        if self.cfg.backend == "xla":
            N = dims[1]
            # zero padding entries are (col 0, val 0, row 0): harmless
            mat = (jnp.zeros((S, N), jnp.int32), jnp.zeros((S, N), md),
                   jnp.zeros((S, N), jnp.int32))
        else:
            _, T, L, _ = dims
            R = self.cfg.block_rows
            mat = (jnp.zeros((S, B, T), jnp.int32),
                   jnp.zeros((S, B, T, L, R), md),
                   jnp.zeros((S, B, T, L, R), jnp.int32))
        diag = jnp.ones((S, n_pad), vd)
        zeros = jnp.zeros((S, n_pad), vd)
        state = BatchedCGState(
            k=jnp.zeros((), jnp.int32), it=jnp.zeros(S, jnp.int32),
            x=zeros, r=zeros, p=zeros, rz=jnp.zeros(S, vd),
            rr=jnp.zeros(S, vd), active=jnp.zeros(S, bool),
            trace=jnp.zeros((S, 0), vd))
        tol = jnp.full(S, self.cfg.tol, vd)
        maxiter_vec = jnp.zeros(S, jnp.int32)

        if old_mat is not None:
            # Growing the bucket: copy every old lane into the new arrays.
            def grow(new, old):
                pads = [(0, n - o) for n, o in zip(new.shape, old.shape)]
                return jnp.pad(old, pads)
            mat = tuple(grow(n, o) for n, o in zip(mat, old_mat))
            diag = diag.at[:, : old_state.x.shape[1]].set(self._diag)
            state = BatchedCGState(
                k=old_state.k, it=old_state.it,
                x=zeros.at[:, : old_state.x.shape[1]].set(old_state.x),
                r=zeros.at[:, : old_state.r.shape[1]].set(old_state.r),
                p=zeros.at[:, : old_state.p.shape[1]].set(old_state.p),
                rz=old_state.rz, rr=old_state.rr, active=old_state.active,
                trace=state.trace)
            tol, maxiter_vec = self._tol, self._maxiter_vec
        self._bucket = dims
        self._mat = mat
        self._diag = diag
        self._state = state
        self._tol = tol
        self._maxiter_vec = maxiter_vec

    # ------------------------------------------------------------ public
    @property
    def free_slots(self) -> int:
        return sum(r is None for r in self._req_of_slot)

    @property
    def active_count(self) -> int:
        return 0 if self._state is None else int(self._state.active.sum())

    def submit(self, a, b=None, x0=None, *, tol: Optional[float] = None,
               maxiter: Optional[int] = None) -> int:
        """Admit one SPD system into a free slot; returns the request id."""
        self._harvest()        # a lane done since the last tick frees its slot
        free = [s for s, r in enumerate(self._req_of_slot) if r is None]
        if not free:
            raise RuntimeError("no free solver slots")
        s = free[0]
        cfg = self.cfg
        a = _as_csr(a)
        if cfg.backend == "xla":
            m = csr_to_bell(a, block_rows=cfg.block_rows,
                            col_tile=cfg.col_tile)
        else:
            m = csr_to_ellpack(a, block_rows=cfg.block_rows,
                               col_tile=cfg.col_tile)
        dims = tuple(bucket_up(d) for d in self._dims_of(m))
        if self._bucket is None or any(d > o for d, o in
                                       zip(dims, self._bucket)):
            grown = dims if self._bucket is None else tuple(
                max(d, o) for d, o in zip(dims, self._bucket))
            self._alloc(grown)
        if cfg.backend == "xla":
            gc, v, rw = flatten_bell(m)
            N = self._bucket[1]
            lanes = tuple(np.pad(x, (0, N - x.shape[0]))
                          for x in (gc, v, rw))
        else:
            B, T, L, _ = self._bucket
            m = pad_ellpack(m, n_row_blocks=B, n_slabs=T, ell=L)
            lanes = (m.tile_cols, m.vals, m.local_cols)
        self._mat = tuple(
            arr.at[s].set(jnp.asarray(lane).astype(arr.dtype))
            for arr, lane in zip(self._mat, lanes))

        vd = self.scheme.vector_dtype
        n = a.shape[0]
        n_pad = self._diag.shape[1]
        d = np.ones(n_pad)
        d[:n] = a.diagonal()
        bb = np.zeros(n_pad)
        bb[:n] = np.ones(n) if b is None else np.asarray(b)
        xx = np.zeros(n_pad)
        if x0 is not None:
            xx[:n] = np.asarray(x0)
        diag_l = jnp.asarray(d, vd)
        b_l = jnp.asarray(bb, vd)
        x0_l = jnp.asarray(xx, vd)
        self._diag = self._diag.at[s].set(diag_l)

        n_tiles = self._bucket[-1]
        if cfg.backend == "xla":
            gc, v, rw = (arr[s] for arr in self._mat)
            r, z, rz, rr = _lane_init_flat(
                gc, v, rw, diag_l, b_l, x0_l, n_rows=n_pad,
                padded_cols=n_tiles * cfg.col_tile, scheme=self.scheme)
        else:
            tc, v, lc = (arr[s] for arr in self._mat)
            r, z, rz, rr = _lane_init_ell(
                tc, v, lc, diag_l, b_l, x0_l, col_tile=cfg.col_tile,
                n_col_tiles=n_tiles, scheme=self.scheme,
                interpret=self.interpret)

        st = self._state
        req_tol = jnp.asarray(cfg.tol if tol is None else tol, vd)
        self._state = BatchedCGState(
            k=st.k, it=st.it.at[s].set(0),
            x=st.x.at[s].set(x0_l), r=st.r.at[s].set(r),
            p=st.p.at[s].set(z), rz=st.rz.at[s].set(rz),
            rr=st.rr.at[s].set(rr),
            active=st.active.at[s].set(rr > req_tol), trace=st.trace)
        self._tol = self._tol.at[s].set(req_tol)
        self._maxiter_vec = self._maxiter_vec.at[s].set(
            cfg.maxiter if maxiter is None else maxiter)

        rid = self._next_id
        self._next_id += 1
        self._req_of_slot[s] = rid
        self._n_of_slot[s] = n
        return rid

    def step(self) -> Dict[int, CGResult]:
        """One chunked tick (≤ ``chunk_iters`` iterations for every live
        lane); harvests and frees slots that finished, returning
        ``{request_id: CGResult}``."""
        if self._state is None or not bool(self._state.active.any()):
            return self._harvest()
        cfg = self.cfg
        stepper = make_batched_stepper(
            backend=cfg.backend, scheme=self.scheme,
            block_rows=cfg.block_rows, col_tile=cfg.col_tile,
            n_col_tiles=self._bucket[-1], n_row_blocks=self._bucket[0],
            chunk=cfg.chunk_iters, interpret=self.interpret)
        self._state = stepper(self._mat, self._diag, self._state,
                              self._tol, self._maxiter_vec)
        return self._harvest()

    def _harvest(self) -> Dict[int, CGResult]:
        if self._state is None:
            return {}
        done: Dict[int, CGResult] = {}
        active = np.asarray(self._state.active)
        its = np.asarray(self._state.it)
        rrs = np.asarray(self._state.rr)
        tols = np.asarray(self._tol)
        for s, rid in enumerate(self._req_of_slot):
            if rid is None or active[s]:
                continue
            n = int(self._n_of_slot[s])
            res = CGResult(
                x=self._state.x[s, :n], iterations=int(its[s]),
                rr=float(rrs[s]), converged=bool(rrs[s] <= tols[s]),
                residual_trace=None, scheme=self.scheme.name,
                method="vsr_batched")
            done[rid] = res
            self.results[rid] = res
            self._req_of_slot[s] = None
        return done

    def run_to_completion(self, max_ticks: int = 10_000) -> Dict[int, CGResult]:
        """Tick until every admitted system finished; returns all results
        harvested during the call.  Raises if ``max_ticks`` elapses with
        lanes still live (truncation must be observable, not a silently
        missing request id)."""
        out: Dict[int, CGResult] = {}
        out.update(self._harvest())
        ticks = 0
        while self._state is not None and bool(self._state.active.any()):
            if ticks >= max_ticks:
                live = [rid for s, rid in enumerate(self._req_of_slot)
                        if rid is not None and bool(self._state.active[s])]
                raise RuntimeError(
                    f"run_to_completion hit max_ticks={max_ticks} with "
                    f"requests {live} still active (chunk_iters="
                    f"{self.cfg.chunk_iters}); raise max_ticks or maxiter")
            out.update(self.step())
            ticks += 1
        return out
