"""Int8-quantized KV cache — the paper's Mix-V3 principle, one tier
further down the serving stack.

Callipepla stores the *streamed operand* (the sparse matrix) one
precision tier below the iterate and casts in-register (§6).  Decode is
the same regime: the KV cache is the streamed operand (memory term =
cache bytes / HBM bw, §Roofline), the query/output are the "iterate".
So: store K/V **int8 with one scale per (batch, head, position)**,
dequantize in-register at the score/output einsums, keep q and softmax at
bf16/fp32.  Cache bytes halve vs bf16 ⇒ the decode memory roofline
halves, exactly as Mix-V3 halves the SpMV stream.

Accuracy: per-position scales are the KV-quant standard (row-wise
absmax); `tests/test_quant_cache.py` bounds the decode error vs the bf16
reference and checks end-to-end argmax agreement.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import _NEG, _split_heads
from repro.models.layers import apply_rope, dense, rope_freqs

__all__ = ["QuantAttnCache", "init_quant_cache", "attn_decode_quant",
           "quantize_kv", "dequantize_kv"]


@dataclasses.dataclass(frozen=True)
class QuantAttnCache:
    """Head-major int8 KV cache: values [B, Hk, T, D] i8 + per-(b,h,t)
    scales.  ``ring`` static, as in AttnCache."""
    k: jax.Array           # int8 [B, Hk, T, D]
    v: jax.Array           # int8 [B, Hk, T, D]
    k_scale: jax.Array     # f32 [B, Hk, T]
    v_scale: jax.Array     # f32 [B, Hk, T]
    ring: bool


jax.tree_util.register_dataclass(
    QuantAttnCache, data_fields=["k", "v", "k_scale", "v_scale"],
    meta_fields=["ring"])


def init_quant_cache(batch: int, length: int, n_kv_heads: int,
                     head_dim: int, *, ring: bool = False) -> QuantAttnCache:
    shape = (batch, n_kv_heads, length, head_dim)
    return QuantAttnCache(
        k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
        k_scale=jnp.zeros(shape[:3], jnp.float32),
        v_scale=jnp.zeros(shape[:3], jnp.float32), ring=ring)


def quantize_kv(x: jax.Array):
    """x [..., D] -> (int8 values, f32 scale over the last dim)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def attn_decode_quant(p, x: jax.Array, cache: QuantAttnCache,
                      pos: jax.Array, *, n_heads: int, n_kv_heads: int,
                      head_dim: int, window: Optional[int] = None,
                      rope_theta: float = 10_000.0):
    """One-token decode against the int8 cache.

    Same contract as ``attn_decode``; the dequantize happens in-register
    at the einsum (the Mix-V3 cast point).  Returns (y, new cache).
    """
    b = x.shape[0]
    length = cache.k.shape[2]
    q = _split_heads(dense(p["wq"], x), n_heads, head_dim)
    k = _split_heads(dense(p["wk"], x), n_kv_heads, head_dim)
    v = _split_heads(dense(p["wv"], x), n_kv_heads, head_dim)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    cos, sin = rope_freqs(pos[:, None], head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = pos % length if cache.ring else pos
    bidx = jnp.arange(b)[:, None]
    hidx = jnp.arange(n_kv_heads)[None, :]
    kq, ks = quantize_kv(k[:, 0])            # [B,Hk,D] i8, [B,Hk] f32
    vq, vs = quantize_kv(v[:, 0])
    ck = cache.k.at[bidx, hidx, slot[:, None]].set(kq)
    cv = cache.v.at[bidx, hidx, slot[:, None]].set(vq)
    cks = cache.k_scale.at[bidx, hidx, slot[:, None]].set(ks)
    cvs = cache.v_scale.at[bidx, hidx, slot[:, None]].set(vs)

    # scores: (q · k_i8) * scale_i — the scale factors out of the dot, so
    # the int8 payload is the only per-position stream
    g = n_heads // n_kv_heads
    qg = q.reshape(b, 1, n_kv_heads, g, head_dim).astype(jnp.float32)
    sc = jnp.einsum("bshgd,bhtd->bhgst", qg, ck.astype(jnp.float32))
    sc = sc * cks[:, :, None, None, :]               # [B,Hk,g,1,T]
    scores = sc.reshape(b, n_heads, 1, length) * (head_dim ** -0.5)

    j = jnp.arange(length)[None, :]
    pb = pos[:, None]
    if cache.ring:
        valid = jnp.where(pb >= length, jnp.ones((b, length), bool),
                          j <= pb)
    else:
        valid = j <= pb
        if window is not None:
            valid &= j > pb - window
    scores = jnp.where(valid[:, None, None, :], scores, _NEG)
    w = jax.nn.softmax(scores, axis=-1)              # fp32

    wg = w.reshape(b, n_kv_heads, g, 1, length)
    wv = wg * cvs[:, :, None, None, :]               # fold scale into w
    o = jnp.einsum("bhgst,bhtd->bshgd", wv, cv.astype(jnp.float32))
    o = o.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    y = dense(p["wo"], o)
    return y, QuantAttnCache(ck, cv, cks, cvs, cache.ring)
