"""Batched decode engine — slot-based continuous batching.

A fixed pool of ``batch_slots`` request slots decodes in lock-step (one
jitted ``serve_step`` per tick, all families); slots are *ragged*: each
carries its own position (``attn_decode`` takes per-slot ``pos``), so a
new request can join mid-flight.  Admission prefills the prompt into the
slot's cache (a ``lax.scan`` of decode steps over a batch-1 view — other
slots' state is untouched), then the slot participates in the shared tick.

Sampling: greedy or temperature, per-slot PRNG.  EOS or ``max_new`` frees
the slot.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec
from repro.models.api import decode_step, init_cache
from repro.models.config import ModelConfig
from repro.serve.kv_cache import slot_insert, slot_view

__all__ = ["EngineConfig", "DecodeEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch_slots: int = 8
    max_len: int = 1024
    temperature: float = 0.0          # 0 = greedy
    eos_token: int = -1               # -1: never
    cache_dtype: str = "bfloat16"
    seed: int = 0


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        B = ecfg.batch_slots
        self.cache = init_cache(cfg, B, ecfg.max_len,
                                dtype=jnp.dtype(ecfg.cache_dtype))
        self.pos = np.zeros(B, np.int32)
        self.active = np.zeros(B, bool)
        self.tokens = np.zeros(B, np.int32)
        self.outputs: List[List[int]] = [[] for _ in range(B)]
        self.max_new = np.zeros(B, np.int32)
        self.generated = np.zeros(B, np.int32)
        self.key = jax.random.PRNGKey(ecfg.seed)
        self._tick = self._build_tick()
        self._prefill = self._build_prefill()

    # ------------------------------------------------------------- jitted
    def _build_tick(self):
        cfg, ecfg = self.cfg, self.ecfg

        @jax.jit
        def tick(params, cache, tokens, pos, active, key):
            logits, new_cache = decode_step(params, cfg, cache, tokens, pos)
            if ecfg.temperature > 0.0:
                nxt = jax.random.categorical(
                    key, logits / ecfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)
            # Frozen slots keep their token and cache row untouched is not
            # needed: their pos does not advance, so next tick overwrites
            # the same cache slot — harmless and branch-free.
            nxt = jnp.where(active, nxt, tokens)
            new_pos = jnp.where(active, pos + 1, pos)
            return nxt, new_pos, new_cache

        return tick

    def _build_prefill(self):
        cfg = self.cfg

        @jax.jit
        def prefill(params, slot_cache, prompt):      # prompt [P] int32
            def step(carry, tok):
                c, p = carry
                logits, c = decode_step(params, cfg, c, tok[None], p)
                return (c, p + 1), logits

            (c, p), logits = jax.lax.scan(
                step, (slot_cache, jnp.zeros((1,), jnp.int32)), prompt)
            return c, p[0], logits[-1, 0]

        return prefill

    # ------------------------------------------------------------- public
    def add_request(self, prompt: List[int], max_new: int = 32,
                    audio_embeds: Optional[jax.Array] = None,
                    patch_embeds=None) -> int:
        """Admit a request into a free slot; returns the slot id."""
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            raise RuntimeError("no free slots")
        s = int(free[0])
        slot = slot_view(self.cache, s)
        if self.cfg.encoder is not None:
            assert audio_embeds is not None, "audio arch needs embeddings"
            enc = encdec.encode(self.params, self.cfg, audio_embeds[None])
            ck, cv = encdec.prefill_cross(self.params, self.cfg, enc)
            slot = dict(slot)
            slot["cross_k"], slot["cross_v"] = (
                ck.astype(slot["cross_k"].dtype),
                cv.astype(slot["cross_v"].dtype))
        slot, pos, logits = self._prefill(
            self.params, slot, jnp.asarray(prompt, jnp.int32))
        self.cache = slot_insert(self.cache, slot, s)
        self.pos[s] = int(pos)
        first = int(jnp.argmax(logits))
        self.tokens[s] = first
        self.outputs[s] = [first]
        self.active[s] = True
        self.max_new[s] = max_new
        self.generated[s] = 1
        return s

    def step(self) -> Dict[int, int]:
        """One synchronized decode tick; returns {slot: new_token}."""
        if not self.active.any():
            return {}
        self.key, sub = jax.random.split(self.key)
        nxt, new_pos, self.cache = self._tick(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(self.active), sub)
        nxt = np.array(nxt)                   # copies: keep host state mutable
        self.pos = np.array(new_pos)
        out = {}
        for s in np.flatnonzero(self.active):
            t = int(nxt[s])
            self.tokens[s] = t
            self.outputs[s].append(t)
            self.generated[s] += 1
            out[int(s)] = t
            done = (t == self.ecfg.eos_token
                    or self.generated[s] >= self.max_new[s]
                    or self.pos[s] >= self.ecfg.max_len - 1)
            if done:
                self.active[s] = False
        return out

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while self.active.any() and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.outputs
