"""Serving substrate: KV/state caches + slot-based batched decode engine
(+ int8 quantized cache — Mix-V3 one tier further; + slot-based batched
CG solver engine running on the stream VM — continuous batching for
linear systems with per-request VSR policy and precision scheme)."""
from repro.serve.engine import DecodeEngine, EngineConfig
from repro.serve.kv_cache import (bytes_per_slot, cache_bytes, init_cache,
                                  slot_insert, slot_view)
from repro.serve.solver_engine import SolverEngine, SolverEngineConfig
from repro.serve.quant_cache import (QuantAttnCache, attn_decode_quant,
                                     init_quant_cache)

__all__ = ["DecodeEngine", "EngineConfig", "SolverEngine",
           "SolverEngineConfig", "bytes_per_slot", "cache_bytes",
           "init_cache", "slot_insert", "slot_view", "QuantAttnCache",
           "attn_decode_quant", "init_quant_cache"]
