"""whisper-base [audio] — encoder-decoder, conv frontend stub.

6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865 [arXiv:2212.04356].
``input_specs`` supplies precomputed frame embeddings [B, 1500, 512] (the
conv1d×2+GELU frontend output).  Whisper flavor: LayerNorm + GELU MLP +
attention biases; the decoder's learned 448-position table is replaced by
RoPE so the assigned 4k/32k decoder shapes are well-defined (DESIGN.md).
"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                      # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    head_dim=64,
    qkv_bias=True,
    norm_kind="ln",
    mlp_kind="gelu",
    encoder=EncoderConfig(n_layers=6, n_ctx=1500),
)
