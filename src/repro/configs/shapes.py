"""Assigned input shapes — every (arch × shape) dry-run cell is defined here.

  train_4k      seq 4,096    global_batch 256   -> train_step
  prefill_32k   seq 32,768   global_batch 32    -> prefill (forward logits)
  decode_32k    seq 32,768   global_batch 128   -> serve_step (1 new token,
                                                   KV cache of seq_len)
  long_500k     seq 524,288  global_batch 1     -> serve_step; requires
                                                   sub-quadratic attention
"""
from __future__ import annotations

import dataclasses

__all__ = ["Shape", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # "train" | "prefill" | "decode"
    needs_subquadratic: bool = False


SHAPES = {
    "train_4k":    Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   Shape("long_500k", 524_288, 1, "decode",
                         needs_subquadratic=True),
}
