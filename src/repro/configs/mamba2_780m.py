"""mamba2-780m [ssm] — attention-free SSD (state-space duality) stack.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060].
d_inner = 3072, headdim 64 ⇒ 48 SSD heads.  Decode state is O(1) in
sequence length ⇒ all four shapes including `long_500k` run.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # attention-free: unused
    n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    head_dim=64,
    ssm=SSMConfig(d_state=128, expand=2, d_conv=4, headdim=64, chunk=256),
)
