"""internvl2-76b [vlm] — InternViT frontend stub + InternLM2-style backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821].
``input_specs`` supplies 256 precomputed patch embeddings [B, 256, 8192]
(the InternViT + pixel-shuffle + MLP projector output) prepended to the
token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=128_256,
    head_dim=128,
    n_patches=256,
    tie_embeddings=False,
)
