"""gemma3-1b [dense] — 5:1 local:global attention interleave, 262k vocab.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
[hf:google/gemma-3-1b-pt].  Local layers use a 512-token sliding window
(ring KV cache), every 6th layer is global ⇒ `long_500k` runs; the global
layers' O(S) decode cost is the noted caveat (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262_144,
    head_dim=256,
    sliding_window=512,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
)
