"""Architecture registry — ``--arch <id>`` resolution + dry-run input specs.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (architecture × shape) cell: weak-type-correct,
shardable, **no device allocation** — the dry-run lowers ``train_step`` /
``prefill_step`` / ``serve_step`` against them.

``applicable(cfg, shape)`` encodes the assignment's skip rules:
`long_500k` needs sub-quadratic attention (SSM / hybrid / windowed);
pure full-attention archs record ``SKIP(reason)``.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, Shape
from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "input_specs", "applicable", "SHAPES",
           "Shape", "cells"]

#: arch id -> module (one file per assigned architecture)
ARCHS = {
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-base": "whisper_base",
    "granite-34b": "granite_34b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen2.5-32b": "qwen2_5_32b",
    "gemma3-1b": "gemma3_1b",
    "mamba2-780m": "mamba2_780m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "internvl2-76b": "internvl2_76b",
}


def get_config(arch: str) -> ModelConfig:
    if isinstance(arch, ModelConfig):
        return arch
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def applicable(cfg: ModelConfig, shape: Shape) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.needs_subquadratic and not cfg.supports_long_context:
        return False, ("full attention is O(S^2)/O(S)-state at 500k; "
                       "skip per assignment (sub-quadratic archs only)")
    if shape.kind == "decode" and cfg.encoder is not None \
            and shape.needs_subquadratic:
        return False, "enc-dec decoder is full-attention at 500k"
    return True, ""


def _extras(cfg: ModelConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.n_patches:
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), dt)
    if cfg.encoder is not None:
        out["audio_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.n_ctx, cfg.d_model), dt)
    return out


def input_specs(arch, shape_name: str,
                cache_dtype=jnp.bfloat16) -> Dict[str, object]:
    """ShapeDtypeStruct inputs for one (arch × shape) cell.

    train:   {tokens, labels} (+frontend embeds)
    prefill: {tokens} (+frontend embeds)
    decode:  {token, pos, cache} — cache shapes via ``jax.eval_shape`` over
             the model's ``init_cache`` (no allocation).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape_name}: SKIP({why})")
    i32 = jnp.int32

    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch,
                                            shape.seq_len), i32),
            "labels": jax.ShapeDtypeStruct((shape.global_batch,
                                            shape.seq_len), i32),
        }
        specs.update(_extras(cfg, shape.global_batch))
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), i32)}
        specs.update(_extras(cfg, shape.global_batch))
        return specs

    # decode: one new token against a cache of seq_len context
    from repro.models.api import init_cache
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           dtype=cache_dtype))
    return {
        "token": jax.ShapeDtypeStruct((shape.global_batch,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache,
    }


def cells(archs=None, shapes=None):
    """Iterate (arch, shape, runs?, skip_reason) over the full matrix."""
    archs = archs or list(ARCHS)
    shapes = shapes or list(SHAPES)
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            ok, why = applicable(cfg, SHAPES[s])
            yield a, s, ok, why
