"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E].  Early fusion is multimodal input
plumbing — the assigned shapes are text-only, so the frontend is N/A here
(DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=1, capacity_factor=1.25),
    tie_embeddings=False,
)
