"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  The shared transformer block (attention + MLP,
weights reused) fires every 6 SSD layers; the released checkpoints' LoRA
per-invocation deltas are omitted (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    head_dim=64,
    ssm=SSMConfig(d_state=64, expand=2, d_conv=4, headdim=64, chunk=256),
    attn_every=6,
)
