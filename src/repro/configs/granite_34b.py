"""granite-34b [dense] — code model, MQA (single KV head).

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152 [arXiv:2405.04324; hf].
GPT-BigCode lineage: 2-matrix GELU MLP (d_ff = 4·d_model) — with it the
param count lands at ~34B as published; a SwiGLU MLP would be ~47B.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab=49_152,
    head_dim=128,
    mlp_kind="gelu",
    norm_kind="ln",
)
