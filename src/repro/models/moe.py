"""Mixture-of-experts FFN — top-k routing with capacity, EP-shardable.

Dispatch is *scatter-based* (position-in-expert via a cumsum rank), not
one-hot-einsum based: the dense dispatch tensor ``[tokens, E, C]`` that the
classic Mesh-TF formulation materializes would be ~100 MB/device at the
32k-prefill shapes, while the scatter form keeps only the ``[E, C, D]``
expert buffers.  Expert weights carry a leading ``E`` axis that the
distributed layer shards on the ``model`` axis (expert parallelism); the
token→expert scatter then lowers to the all-to-all exchange.

Capacity: ``C = ceil(tokens · top_k · capacity_factor / E)`` tokens per
expert; overflow tokens are dropped (weight renormalized over surviving
experts — standard Switch/GShard semantics).  The router computes in fp32.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import init_dense, init_mlp

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, d: int, f: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, ke = jax.random.split(key)
    # Expert weights: stacked on a leading E axis (the EP shard axis).
    keys = jax.random.split(ke, 3)
    E = cfg.n_experts
    return {
        "router": init_dense(kr, d, E, dtype=dtype),
        "wi": (jax.random.truncated_normal(keys[0], -2, 2, (E, d, f),
                                           jnp.float32) * d ** -0.5).astype(dtype),
        "wg": (jax.random.truncated_normal(keys[1], -2, 2, (E, d, f),
                                           jnp.float32) * d ** -0.5).astype(dtype),
        "wo": (jax.random.truncated_normal(keys[2], -2, 2, (E, f, d),
                                           jnp.float32) * f ** -0.5).astype(dtype),
    }


#: tokens per routing group (GShard-style).  Groups shard on the data
#: axis; the dispatch tensor per device is [G/dp, GROUP, E/mp, C] — small.
GROUP = 1024


def moe_ffn(p, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """x: [B, S, D] -> [B, S, D].  Router/combine in fp32.

    Dispatch is the GShard grouped-einsum form: tokens are partitioned
    into fixed groups, positions-in-expert come from an in-group cumsum,
    and dispatch/combine are one-hot einsums.  An earlier scatter-based
    dispatch was *replicated* by the SPMD partitioner ("involuntary full
    rematerialization") costing ~17 GB/device at the 32k shapes — einsum
    dispatch shards cleanly (EXPERIMENTS.md §Perf).
    """
    from repro.distributed import hints

    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n = b * s
    g_sz = min(GROUP, n)
    n_pad = math.ceil(n / g_sz) * g_sz
    xt = x.reshape(n, d)
    if n_pad != n:
        xt = jnp.concatenate(
            [xt, jnp.zeros((n_pad - n, d), x.dtype)], axis=0)
    G = n_pad // g_sz
    xg = hints.hint(xt.reshape(G, g_sz, d), hints.DATA, None, None)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                  # [G, S, E]
    topw, tope = jax.lax.top_k(gates, K)                     # [G, S, K]
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    cap = max(1, math.ceil(g_sz * K * cfg.capacity_factor / E))

    # Position of each (token, k) among same-expert picks within the
    # group: exclusive cumsum over the flattened (S, K) order.
    sel = jax.nn.one_hot(tope, E, dtype=jnp.int32)           # [G, S, K, E]
    flat = sel.reshape(G, g_sz * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # exclusive
    pos = jnp.sum(pos.reshape(G, g_sz, K, E) * sel, axis=-1)  # [G, S, K]
    keep = pos < cap
    w_kept = jnp.where(keep, topw, 0.0)

    # dispatch [G, S, E, C] (bf16 one-hot; E shards on model, G on data)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=x.dtype)                   # [G, S, K, C]
    disp = jnp.einsum("gske,gskc->gsec", sel.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", sel.astype(jnp.float32),
                      pos_oh.astype(jnp.float32), w_kept)

    xe = jnp.einsum("gsec,gsd->egcd", disp, xg)              # [E, G, C, D]
    xe = hints.hint(xe, hints.MODEL, hints.DATA, None, None)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe,
                               p["wg"].astype(x.dtype))) \
        * jnp.einsum("egcd,edf->egcf", xe, p["wi"].astype(x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(x.dtype))

    y = jnp.einsum("gsec,egcd->gsd", comb,
                   ye.astype(jnp.float32))                   # [G, S, D]
    # cast BEFORE the group->batch reshape and pin the sharding: the f32
    # [G,S,D] reshape to a (batch, seq-model)-sharded target is one GSPMD
    # cannot reshard efficiently — it replicated the full 21 GB tensor
    # per device at the multi-pod 32k shapes (EXPERIMENTS.md §Perf M9)
    y = hints.hint(y.astype(x.dtype), hints.DATA, None, None)
    y = y.reshape(n_pad, d)[:n]
    return hints.hint(y.reshape(b, s, d), hints.DATA, None, None)
