"""Elementary layers — pure-JAX, params as nested dicts of arrays.

Every ``init_*`` returns a params pytree; every ``apply`` function is pure
and shape-polymorphic over leading batch dims.  Compute runs at
``cfg.dtype`` (bf16 on TPU) with fp32 params — the same
"operator one tier below the iterate" principle as the paper's Mix-V3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_dense", "dense", "init_rmsnorm", "rmsnorm", "init_layernorm",
           "layernorm", "norm", "init_norm", "init_embedding", "embed",
           "unembed", "init_mlp", "mlp", "init_mlp_gelu", "mlp_gelu", "ffn",
           "rope_freqs", "apply_rope"]


def _tn(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------- dense
def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": _tn(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: jax.Array, compute_dtype=None) -> jax.Array:
    dt = compute_dtype or x.dtype
    y = x.astype(dt) @ p["w"].astype(dt)
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y


# ------------------------------------------------------------------- rmsnorm
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)                      # norm stats in fp32
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(dt) * p["g"].astype(dt)


# ----------------------------------------------------------------- layernorm
def init_layernorm(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["g"].astype(dt) + p["b"].astype(dt)


def norm(p, x: jax.Array, eps: float) -> jax.Array:
    """Dispatch on param structure: LayerNorm iff a bias is present."""
    return layernorm(p, x, eps) if "b" in p else rmsnorm(p, x, eps)


def init_norm(d: int, kind: str = "rms", dtype=jnp.float32):
    return init_layernorm(d, dtype) if kind == "ln" else init_rmsnorm(d, dtype)


# ----------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"e": _tn(key, (vocab, d), 1.0, dtype)}


def embed(p, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["e"], tokens, axis=0).astype(compute_dtype)


def unembed(p, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits in fp32 (loss numerics)."""
    return x.astype(jnp.float32) @ p["e"].astype(jnp.float32).T


# ---------------------------------------------------------------------- mlp
def init_mlp(key, d: int, f: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": init_dense(k1, d, f, dtype=dtype),
            "wg": init_dense(k2, d, f, dtype=dtype),
            "wo": init_dense(k3, f, d, dtype=dtype, scale=f ** -0.5)}


def mlp(p, x: jax.Array) -> jax.Array:
    """SwiGLU: wo(silu(wg x) * wi x)."""
    h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    return dense(p["wo"], h)


def init_mlp_gelu(key, d: int, f: int, dtype=jnp.float32):
    """2-matrix GELU MLP (whisper-style)."""
    k1, k2 = jax.random.split(key)
    return {"wi": init_dense(k1, d, f, bias=True, dtype=dtype),
            "wo": init_dense(k2, f, d, bias=True, dtype=dtype,
                             scale=f ** -0.5)}


def mlp_gelu(p, x: jax.Array) -> jax.Array:
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x)))


def ffn(p, x: jax.Array) -> jax.Array:
    """Dispatch on param structure: SwiGLU iff a gate matrix is present."""
    return mlp(p, x) if "wg" in p else mlp_gelu(p, x)


# --------------------------------------------------------------------- rope
def rope_freqs(positions: jax.Array, head_dim: int,
               theta: float = 10_000.0):
    """cos/sin tables for ``positions`` (any shape) -> (*pos, head_dim/2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, D]; cos/sin: [..., S, D/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)           # add head axis
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
