"""Attention — GQA/MQA, sliding-window, local:global, cross, KV-cache decode.

One implementation covers every assigned attention variant:

* **GQA/MQA** — ``n_kv_heads`` ≤ ``n_heads``; queries grouped per kv head.
* **Sliding window** (h2o-danube, gemma3 local layers) — the mask keeps
  ``(i − w, i]``; the decode path uses a **ring KV cache** of length ``w``
  so `long_500k` holds O(w) state, not O(S).
* **local:global interleave** (gemma3) — the per-layer window scalar is the
  only difference between layer kinds, so a scanned stack needs no branch.
* **cross attention** (whisper decoder) — kv from the encoder, no mask,
  no RoPE.

Softmax statistics are computed in fp32 regardless of compute dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import hints
from repro.models.layers import apply_rope, dense, init_dense, rope_freqs

__all__ = ["init_attention", "attention", "AttnCache", "init_attn_cache",
           "attn_decode"]

_NEG = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, bias: bool = False, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, bias=bias, dtype=dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, bias=bias, dtype=dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, bias=bias, dtype=dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype=dtype,
                         scale=(n_heads * head_dim) ** -0.5),
    }


def _split_heads(x: jax.Array, n: int, d: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, d)


def _repeat_kv(kv: jax.Array, hq: int) -> jax.Array:
    """GQA via head repetition: [B,T,Hk,D] -> [B,T,Hq,D].

    The repeat (vs. a 5-D grouped einsum) keeps every attention einsum a
    plain head-batched matmul whose HEAD axis GSPMD can shard on `model`;
    the grouped form tempts the partitioner into sharding the head_dim
    contraction, which all-reduces the full score tensor per layer
    (observed, EXPERIMENTS.md §Perf).
    """
    hk = kv.shape[2]
    if hk == hq:
        return kv
    return jnp.repeat(kv, hq // hk, axis=2)


def _gqa_scores_grouped(q: jax.Array, k: jax.Array) -> jax.Array:
    """Decode-path GQA: grouped einsum, NO kv repetition — repeating a
    32k-entry cache ×(Hq/Hk) costs ~20 GB/device; with a 1-token query the
    grouped form has no large intermediate at all."""
    b, s, hq, dd = q.shape
    hk = k.shape[2]
    qg = q.reshape(b, s, hk, hq // hk, dd)
    sc = jnp.einsum("bshgd,bthd->bhgst", qg, k)
    return sc.reshape(b, hq, s, k.shape[1])


def _gqa_out_grouped(w: jax.Array, v: jax.Array) -> jax.Array:
    b, hq, s, t = w.shape
    hk = v.shape[2]
    wg = w.reshape(b, hk, hq // hk, s, t)
    o = jnp.einsum("bhgst,bthd->bshgd", wg, v)
    return o.reshape(b, s, hq, v.shape[-1])


def _gqa_scores(q: jax.Array, k: jax.Array,
                head_hint: bool = False) -> jax.Array:
    """q [B,S,Hq,D], k [B,T,Hk,D] -> scores [B,Hq,S,T]."""
    k = _repeat_kv(k, q.shape[2])
    if head_hint:       # full-seq path only: decode keeps the cache's
        # NB: batch must stay on DATA here — a bare None would be a hard
        # "replicate" constraint and GSPMD then all-gathers the global
        # K tensor on every chip (observed: 21 GB/device at 32k prefill).
        k = hints.hint(k, hints.DATA, None, hints.MODEL, None)
    return jnp.einsum("bshd,bthd->bhst", q, k)


def _gqa_out(w: jax.Array, v: jax.Array,
             head_hint: bool = False) -> jax.Array:
    """w [B,Hq,S,T], v [B,T,Hk,D] -> [B,S,Hq,D]."""
    v = _repeat_kv(v, w.shape[1])
    if head_hint:
        v = hints.hint(v, hints.DATA, None, hints.MODEL, None)
    return jnp.einsum("bhst,bthd->bshd", w, v)


#: sequences at or above this length use the Q-chunked (flash-style) path.
CHUNKED_ABOVE = 8192
Q_CHUNK = 1024


def _pick_chunk(s: int, target: int) -> Optional[int]:
    """Largest divisor of ``s`` that is ≤ target and a multiple of 8 —
    handles ragged sequences like the VLM's 32768+256 patch prefix
    (whose 33024 length would otherwise fall back to the O(S²) path)."""
    for c in range(min(target, s), 7, -1):
        if s % c == 0 and c % 8 == 0:
            return c
    return None


def _masked_softmax_attn(q, k, v, positions_q, positions_k, *, causal,
                         window, head_dim, compute_dtype):
    """scores -> mask -> softmax -> out for one q block (fp32 softmax)."""
    scores = _gqa_scores(q, k, head_hint=True).astype(jnp.float32) \
        * (head_dim ** -0.5)
    if causal or window is not None:
        i = positions_q[:, :, None]                  # [B|1, Sq, 1]
        j = positions_k[:, None, :]                  # [B|1, 1, T]
        mask = jnp.ones(jnp.broadcast_shapes(i.shape, j.shape), bool)
        if causal:
            mask &= j <= i
        if window is not None:
            mask &= j > i - window
        scores = jnp.where(mask[:, None, :, :], scores, _NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    return _gqa_out(w, v, head_hint=True)


def attention(p, x: jax.Array, *, n_heads: int, n_kv_heads: int,
              head_dim: int, positions: Optional[jax.Array] = None,
              window: Optional[int] = None, causal: bool = True,
              rope_theta: float = 10_000.0,
              cross_kv: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention.  x: [B, S, D].  Returns [B, S, D].

    ``cross_kv`` [B, T, D] switches to encoder-decoder cross attention
    (mask-free, RoPE-free).  ``window``: sliding-window width (None = full).

    Long sequences (S ≥ ``CHUNKED_ABOVE``) run a **query-chunked** pass —
    a ``lax.scan`` over Q blocks so the [Sq, T] score tile, not the full
    [S, S] matrix, is the peak live tensor (the memory move that makes the
    32k-prefill shapes fit; same spirit as flash attention, with the full
    row softmax computed per block).
    """
    b, s, _ = x.shape
    q = _split_heads(dense(p["wq"], x), n_heads, head_dim)
    kv_src = cross_kv if cross_kv is not None else x
    k = _split_heads(dense(p["wk"], kv_src), n_kv_heads, head_dim)
    v = _split_heads(dense(p["wv"], kv_src), n_kv_heads, head_dim)

    if cross_kv is None:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        cos, sin = rope_freqs(positions, head_dim, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        positions = jnp.arange(s)[None, :]
        causal = False
        window = None
    q = hints.hint(q, hints.DATA, None, hints.MODEL, None)

    pos_k = positions if cross_kv is None else jnp.arange(k.shape[1])[None]

    chunk = _pick_chunk(s, Q_CHUNK) if s >= CHUNKED_ABOVE else None
    if chunk is not None and positions.shape[0] == 1:
        nq = s // chunk
        qc = q.reshape(b, nq, chunk, n_heads, head_dim)
        pq = positions.reshape(1, nq, chunk)

        def blk(_, inp):
            qb, pb = inp                       # [b, Qc, H, D], [1, Qc]
            ob = _masked_softmax_attn(
                qb, k, v, pb, pos_k, causal=causal, window=window,
                head_dim=head_dim, compute_dtype=x.dtype)
            return None, ob

        _, o = jax.lax.scan(blk, None,
                            (qc.swapaxes(0, 1), pq.swapaxes(0, 1)))
        o = o.swapaxes(0, 1).reshape(b, s, n_heads, head_dim)
    else:
        o = _masked_softmax_attn(q, k, v, positions, pos_k, causal=causal,
                                 window=window, head_dim=head_dim,
                                 compute_dtype=x.dtype)
    return dense(p["wo"], o.reshape(b, s, n_heads * head_dim))


# ------------------------------------------------------------------ decode
@dataclasses.dataclass(frozen=True)
class AttnCache:
    """KV cache for one attention layer — stored HEAD-MAJOR.

    Full-context layers: ``k/v [B, Hk, S_max, D]``, slot = position.
    Windowed layers: ``k/v [B, Hk, w, D]`` ring buffer, slot = pos mod w.
    Head-major matches the decode einsum's dot layout directly: the
    seq-major layout cost one 2×cache-slice transpose-copy per layer per
    token (EXPERIMENTS.md §Perf hillclimb 3).  ``ring`` is static
    metadata (not a traced leaf).
    """
    k: jax.Array
    v: jax.Array
    ring: bool


jax.tree_util.register_dataclass(AttnCache, data_fields=["k", "v"],
                                 meta_fields=["ring"])


def init_attn_cache(batch: int, length: int, n_kv_heads: int, head_dim: int,
                    *, ring: bool = False, dtype=jnp.bfloat16) -> AttnCache:
    shape = (batch, n_kv_heads, length, head_dim)
    return AttnCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), ring)


def _scores_headmajor(q: jax.Array, kT: jax.Array) -> jax.Array:
    """q [B,1,Hq,D] × head-major cache kT [B,Hk,T,D] -> [B,Hq,1,T].

    No kv repetition, no transpose: the cache layout already matches the
    dot_general batch/contraction arrangement."""
    b, s, hq, dd = q.shape
    hk = kT.shape[1]
    qg = q.reshape(b, s, hk, hq // hk, dd)
    sc = jnp.einsum("bshgd,bhtd->bhgst", qg, kT)
    return sc.reshape(b, hq, s, kT.shape[2])


def _out_headmajor(w: jax.Array, vT: jax.Array) -> jax.Array:
    """w [B,Hq,1,T] × head-major vT [B,Hk,T,D] -> [B,1,Hq,D]."""
    b, hq, s, t = w.shape
    hk = vT.shape[1]
    wg = w.reshape(b, hk, hq // hk, s, t)
    o = jnp.einsum("bhgst,bhtd->bshgd", wg, vT)
    return o.reshape(b, s, hq, vT.shape[-1])


def attn_decode(p, x: jax.Array, cache: AttnCache, pos: jax.Array, *,
                n_heads: int, n_kv_heads: int, head_dim: int,
                window: Optional[int] = None,
                rope_theta: float = 10_000.0):
    """One-token decode.  x: [B, 1, D]; pos: scalar OR [B] int32 (tokens
    so far, per request slot — ragged continuous batching).

    Returns (y [B, 1, D], updated cache).
    """
    b = x.shape[0]
    length = cache.k.shape[2]
    q = _split_heads(dense(p["wq"], x), n_heads, head_dim)
    k = _split_heads(dense(p["wk"], x), n_kv_heads, head_dim)
    v = _split_heads(dense(p["wv"], x), n_kv_heads, head_dim)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))   # [B]
    cos, sin = rope_freqs(pos[:, None], head_dim, rope_theta)   # [B,1,half]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = pos % length if cache.ring else pos                  # [B]
    # Per-row scatter (NOT dynamic-update-slice): a DUS variant was tried
    # for the uniform-position fast path and REFUTED — XLA's aliasing
    # analysis failed to prove the in-place update safe against the same-
    # iteration read and inserted a full-cache copy per layer (~275 GB/
    # step at 32k); the scatter aliases cleanly (EXPERIMENTS.md §Perf).
    bidx = jnp.arange(b)[:, None]                   # [B,1]
    hidx = jnp.arange(n_kv_heads)[None, :]          # [1,Hk]
    ck = cache.k.at[bidx, hidx, slot[:, None]].set(
        k[:, 0].astype(cache.k.dtype))              # k[:,0]: [B,Hk,D]
    cv = cache.v.at[bidx, hidx, slot[:, None]].set(
        v[:, 0].astype(cache.v.dtype))

    scores = _scores_headmajor(q, ck.astype(x.dtype)).astype(jnp.float32) \
        * (head_dim ** -0.5)                        # [B, Hq, 1, L]
    j = jnp.arange(length)[None, :]                 # [1, L]
    pb = pos[:, None]
    if cache.ring:
        # Ring of length w: slot s holds the most recent position ≡ s
        # (mod w), which is always within the window once written.  Before
        # the first wrap only slots ≤ pos are written.
        valid = jnp.where(pb >= length, jnp.ones((b, length), bool),
                          j <= pb)
    else:
        valid = j <= pb
        if window is not None:
            valid &= j > pb - window
    scores = jnp.where(valid[:, None, None, :], scores, _NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _out_headmajor(w, cv.astype(x.dtype))
    y = dense(p["wo"], o.reshape(b, 1, n_heads * head_dim))
    return y, AttnCache(ck, cv, cache.ring)
