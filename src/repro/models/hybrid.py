"""SSM and hybrid LMs — mamba2-780m (pure SSD stack) and zamba2 (SSD
backbone + one *shared* attention block invoked every ``attn_every``
layers, weights reused across invocations — the zamba2 signature).

Both families run `long_500k`: decode state is O(1) in sequence length for
the SSD layers; zamba2's shared-attention invocations each keep their own
KV cache slot (same weights ≠ same activations).

Simplifications vs. the released zamba2 checkpoints (noted in DESIGN.md):
the shared block's per-invocation LoRA deltas and the concat-input variant
are omitted; the shared block is a standard pre-norm attn+MLP pair.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (AttnCache, attention, attn_decode,
                                    init_attention)
from repro.models.config import ModelConfig
from repro.models.layers import (embed, ffn, init_embedding, init_mlp,
                                 init_norm, norm, unembed)
from repro.models.ssm import (SSMCache, init_mamba2, init_ssm_cache,
                              mamba2_decode, mamba2_forward)

__all__ = ["init_params", "forward", "init_cache", "decode_step"]


def _n_inv(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def _init_ssm_layer(key, cfg: ModelConfig):
    return {"ln": init_norm(cfg.d_model, cfg.norm_kind),
            "ssm": init_mamba2(key, cfg.d_model, cfg.ssm)}


def init_params(cfg: ModelConfig, key: jax.Array):
    ke, kl, ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model),
        "layers": jax.vmap(lambda k: _init_ssm_layer(k, cfg))(layer_keys),
        "ln_f": init_norm(cfg.d_model, cfg.norm_kind),
    }
    if cfg.attn_every:                         # zamba2 shared block
        ka, km = jax.random.split(ks)
        params["shared"] = {
            "ln1": init_norm(cfg.d_model, cfg.norm_kind),
            "attn": init_attention(ka, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd),
            "ln2": init_norm(cfg.d_model, cfg.norm_kind),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff),
        }
    return params


def _shared_block(sp, x, cfg: ModelConfig, positions):
    h = x + attention(sp["attn"], norm(sp["ln1"], x, cfg.norm_eps),
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.hd, positions=positions, causal=True,
                      rope_theta=cfg.rope_theta)
    return h + ffn(sp["mlp"], norm(sp["ln2"], h, cfg.norm_eps))


def _layer_groups(cfg: ModelConfig):
    """Split the layer stack into runs of ``attn_every`` SSD layers, each
    (except a remainder) followed by one shared-attention invocation.
    Returns [(start, length, attn_after?)] — static structure, so the
    forward is grouped scans with the shared block BETWEEN groups instead
    of a per-layer lax.cond (whose untaken branch still costs compile
    size, branch overhead, and poisons cost analysis)."""
    L, every = cfg.n_layers, cfg.attn_every
    if not every:
        return [(0, L, False)]
    out = []
    start = 0
    while start + every <= L:
        out.append((start, every, True))
        start += every
    if start < L:
        out.append((start, L - start, False))
    return out


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra_embeds: Optional[jax.Array] = None,
            last_only: bool = False) -> jax.Array:
    from repro.distributed import hints

    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(dt), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    shared = params.get("shared")

    def body(h, lp):
        h = hints.hint(h, hints.DATA, hints.MODEL, None)   # SP boundary
        # gather the block INPUT (small) so in_proj stays sharded — same
        # Megatron-SP gather-direction fix as transformer._block
        u = hints.hint(norm(lp["ln"], h, cfg.norm_eps),
                       hints.DATA, None, None)
        h = h + hints.hint(
            mamba2_forward(lp["ssm"], u, cfg.d_model, cfg.ssm,
                           norm_eps=cfg.norm_eps),
            hints.DATA, hints.MODEL, None)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    for start, length, attn_after in _layer_groups(cfg):
        lp = jax.tree_util.tree_map(lambda a: a[start:start + length],
                                    params["layers"])
        x, _ = jax.lax.scan(body_fn, x, lp)
        if attn_after and shared is not None:
            x = _shared_block(shared, x, cfg, positions)

    x = norm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    elif extra_embeds is not None:
        x = x[:, extra_embeds.shape[1]:]
    return unembed(params["embed"], x)


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    di = cfg.ssm.d_inner(cfg.d_model)
    h = cfg.ssm.n_ssm_heads(cfg.d_model)
    L = cfg.n_layers
    cache = {"ssm": SSMCache(
        conv=jnp.zeros((L, batch, cfg.ssm.d_conv - 1,
                        di + 2 * cfg.ssm.d_state), dtype),
        ssm=jnp.zeros((L, batch, h, cfg.ssm.headdim, cfg.ssm.d_state),
                      dtype))}
    n_inv = _n_inv(cfg)
    if n_inv:
        shape = (n_inv, batch, cfg.n_kv_heads, max_len, cfg.hd)  # head-major
        cache["attn"] = AttnCache(jnp.zeros(shape, dtype),
                                  jnp.zeros(shape, dtype), False)
    return cache


def decode_step(params, cfg: ModelConfig, cache, token: jax.Array,
                pos: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token[:, None], dt)
    shared = params.get("shared")

    def body(carry, scanned):
        h, = carry
        lp, sc = scanned
        y, sc2 = mamba2_decode(lp["ssm"], norm(lp["ln"], h, cfg.norm_eps),
                               sc, cfg.d_model, cfg.ssm,
                               norm_eps=cfg.norm_eps)
        return (h + y,), sc2

    new_attn = cache.get("attn")
    new_ssm_parts = []
    inv = 0
    for start, length, attn_after in _layer_groups(cfg):
        lp = jax.tree_util.tree_map(lambda a: a[start:start + length],
                                    params["layers"])
        sc = jax.tree_util.tree_map(lambda a: a[start:start + length],
                                    cache["ssm"])
        (x,), sc2 = jax.lax.scan(body, (x,), (lp, sc))
        new_ssm_parts.append(sc2)
        if attn_after and shared is not None:
            # shared weights, but a distinct (statically indexed) KV slot
            # per invocation — same weights ≠ same activations
            c = jax.tree_util.tree_map(lambda a: a[inv], new_attn)
            u = norm(shared["ln1"], x, cfg.norm_eps)
            y2, c2 = attn_decode(shared["attn"], u, c, pos,
                                 n_heads=cfg.n_heads,
                                 n_kv_heads=cfg.n_kv_heads,
                                 head_dim=cfg.hd,
                                 rope_theta=cfg.rope_theta)
            x = x + y2
            x = x + ffn(shared["mlp"], norm(shared["ln2"], x,
                                            cfg.norm_eps))
            new_attn = jax.tree_util.tree_map(
                lambda a, upd, i=inv: a.at[i].set(upd.astype(a.dtype)),
                new_attn, c2)
            inv += 1

    new_ssm = jax.tree_util.tree_map(
        lambda *parts: jnp.concatenate(parts, axis=0), *new_ssm_parts)
    x = norm(params["ln_f"], x, cfg.norm_eps)
    new_cache = {"ssm": new_ssm}
    if new_attn is not None:
        new_cache["attn"] = new_attn
    return unembed(params["embed"], x)[:, 0], new_cache
