"""Mamba2 (SSD — state-space duality) layer, chunked matmul form + decode.

The SSD algorithm [arXiv:2405.21060] computes the selective-SSM recurrence

    h_t = exp(A·dt_t) h_{t-1} + dt_t · B_t ⊗ x_t ,   y_t = C_t · h_t + D·x_t

in a chunk-quadratic / cross-chunk-linear form that is MXU-friendly:
within a chunk of length Q the interaction is a masked [Q, Q] matmul
(exactly a decayed attention score), and chunk boundary states are carried
by a short ``lax.scan``.  Training/prefill use chunks; decode holds the
O(H·P·N) state — this is why the SSM/hybrid archs run the `long_500k`
shape (constant state) while full-attention archs skip it.

Head dim P = ``headdim``, state N = ``d_state``, H = d_inner / P heads,
single B/C group (n_groups = 1).  Heads shard on the `model` mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import SSMConfig
from repro.models.layers import init_dense, init_rmsnorm, rmsnorm

__all__ = ["init_mamba2", "mamba2_forward", "SSMCache", "init_ssm_cache",
           "mamba2_decode"]


def init_mamba2(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    di = cfg.d_inner(d_model)
    h = cfg.n_ssm_heads(d_model)
    n = cfg.d_state
    conv_ch = di + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # z, x, B, C, dt  packed in one projection
        "in_proj": init_dense(k1, d_model, 2 * di + 2 * n + h, dtype=dtype),
        "conv_w": (jax.random.truncated_normal(
            k2, -2, 2, (cfg.d_conv, conv_ch), jnp.float32)
            * cfg.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), dtype),               # A = -exp(A_log) = -1
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": init_dense(k3, di, d_model, dtype=dtype,
                               scale=di ** -0.5),
    }


def _split_proj(p, u, di: int, n: int, h: int):
    z = u[..., :di]
    xbc = u[..., di: di + di + 2 * n]
    dt = u[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: Optional[jax.Array] = None):
    """Depthwise causal conv, width K.  xbc: [B, S, C]; w: [K, C].

    Returns (out [B, S, C], final (K-1)-tap state [B, K-1, C])."""
    k = w.shape[0]
    pad = init_state if init_state is not None else jnp.zeros(
        (xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i: i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(k))
    out = out + b.astype(xbc.dtype)
    return jax.nn.silu(out), xp[:, -(k - 1):]


def _ssd_chunked(x, dt, a_head, B, C, chunk: int,
                 h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x [b,s,h,p]; dt [b,s,h] (post-softplus); a_head [h] (negative);
    B, C [b,s,n].  Returns (y [b,s,h,p], h_last [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    if s % q:                                  # pad tail chunk
        padlen = nc * q - s
        x = jnp.pad(x, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, padlen), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, padlen), (0, 0)))
    xq = x.reshape(b, nc, q, h, p)
    dtq = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bq = B.reshape(b, nc, q, n)
    Cq = C.reshape(b, nc, q, n)

    a = dtq * a_head.astype(jnp.float32)                  # [b,nc,q,h] ≤ 0
    cum = jnp.cumsum(a, axis=2)                           # inclusive
    # ---- intra-chunk (masked decayed attention on the MXU) ----
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    g = jnp.einsum("bcin,bcjn->bcij", Cq.astype(jnp.float32),
                   Bq.astype(jnp.float32))
    w = g[..., None] * decay * dtq[:, :, None, :, :]
    w = jnp.where(mask[None, None, :, :, None], w, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w,
                         xq.astype(jnp.float32))

    # ---- chunk summary states ----
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                # [b,nc,q,h]
    s_c = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", seg * dtq, Bq.astype(jnp.float32),
                     xq.astype(jnp.float32))              # [b,nc,h,p,n]
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # [b,nc,h]

    # ---- cross-chunk recurrence ----
    h_init = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(hprev, inp):
        dec, sc = inp                                     # [b,h], [b,h,p,n]
        hnew = dec[:, :, None, None] * hprev + sc
        return hnew, hprev                                # emit PRE-state

    h_last, h_prevs = jax.lax.scan(
        step, h_init, (chunk_decay.swapaxes(0, 1), s_c.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                      # [b,nc,h,p,n]

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cq.astype(jnp.float32),
                         jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :s]
    return y, h_last


def mamba2_forward(p, x: jax.Array, d_model: int, cfg: SSMConfig, *,
                   norm_eps: float = 1e-6,
                   conv_state: Optional[jax.Array] = None,
                   ssm_state: Optional[jax.Array] = None,
                   return_state: bool = False):
    """Full-sequence Mamba2 block (pre-norm residual NOT included).

    x: [B, S, D] -> [B, S, D]  (+ (conv_state, ssm_state) if requested).
    """
    di = cfg.d_inner(d_model)
    n = cfg.d_state
    h = cfg.n_ssm_heads(d_model)
    pdim = cfg.headdim

    u = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xbc, dt = _split_proj(p, u, di, n, h)
    xbc, conv_out_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                       conv_state)
    xc = xbc[..., :di]
    B = xbc[..., di: di + n]
    C = xbc[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(*xc.shape[:-1], h, pdim)
    # SSD heads shard on `model` — the chunk-quadratic decay tensor
    # [b, nc, q, q, h] is the biggest live tensor and divides by heads.
    from repro.distributed import hints
    xh = hints.hint(xh, hints.DATA, None, hints.MODEL, None)
    dt = hints.hint(dt, hints.DATA, None, hints.MODEL)
    y, h_last = _ssd_chunked(xh, dt, a_head, B, C, cfg.chunk, ssm_state)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], di).astype(x.dtype)
    y = rmsnorm(p["norm"], y, norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"]["w"].astype(x.dtype)
    if return_state:
        return out, (conv_out_state, h_last.astype(x.dtype))
    return out


# ------------------------------------------------------------------ decode
@dataclasses.dataclass(frozen=True)
class SSMCache:
    """Per-layer decode state: conv taps [B, K-1, C] + SSM state
    [B, H, P, N] — constant in sequence length (the long_500k enabler)."""
    conv: jax.Array
    ssm: jax.Array


jax.tree_util.register_dataclass(SSMCache, data_fields=["conv", "ssm"],
                                 meta_fields=[])


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.bfloat16) -> SSMCache:
    di = cfg.d_inner(d_model)
    h = cfg.n_ssm_heads(d_model)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, di + 2 * cfg.d_state), dtype),
        ssm=jnp.zeros((batch, h, cfg.headdim, cfg.d_state), dtype))


def mamba2_decode(p, x: jax.Array, cache: SSMCache, d_model: int,
                  cfg: SSMConfig, *, norm_eps: float = 1e-6):
    """One-token step.  x: [B, 1, D].  Returns (y [B, 1, D], new cache)."""
    di = cfg.d_inner(d_model)
    n = cfg.d_state
    h = cfg.n_ssm_heads(d_model)
    pdim = cfg.headdim

    u = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xbc, dt = _split_proj(p, u, di, n, h)
    # conv over (K-1 cached taps + this token)
    xp = jnp.concatenate([cache.conv.astype(x.dtype), xbc], axis=1)
    k = p["conv_w"].shape[0]
    conv_out = sum(xp[:, i: i + 1] * p["conv_w"][i].astype(x.dtype)
                   for i in range(k)) + p["conv_b"].astype(x.dtype)
    xbc1 = jax.nn.silu(conv_out)                          # [B, 1, C]
    xc = xbc1[..., :di]
    B = xbc1[..., di: di + n][:, 0]                       # [B, N]
    C = xbc1[..., di + n:][:, 0]

    dt1 = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * a_head)                           # [B, H]
    xh = xc.reshape(x.shape[0], h, pdim).astype(jnp.float32)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, B.astype(jnp.float32), xh)
    ssm = dec[:, :, None, None] * cache.ssm.astype(jnp.float32) + upd
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), ssm)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y, norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"]["w"].astype(x.dtype)
    return out, SSMCache(conv=xp[:, -(k - 1):].astype(cache.conv.dtype),
                         ssm=ssm.astype(cache.ssm.dtype))
