"""Encoder-decoder (whisper-base backbone) — conv frontend is a STUB.

Per the assignment, `[audio]` entries specify the transformer backbone
only: ``input_specs()`` supplies precomputed frame embeddings
``[B, n_ctx, d_model]`` (the conv1d×2 + GELU frontend output), so the
encoder here is the 6-layer bidirectional stack over those embeddings with
whisper's sinusoidal positions.  The decoder is causal self-attention +
cross-attention; whisper's learned 448-position table is replaced by RoPE
so the assigned 4k/32k decoder shapes are well-defined (DESIGN.md).

Whisper flavor: LayerNorm + GELU MLP (``norm_kind="ln"``,
``mlp_kind="gelu"``), attention biases.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (AttnCache, attention, attn_decode,
                                    init_attention)
from repro.models.config import ModelConfig
from repro.models.layers import (dense, embed, ffn, init_embedding,
                                 init_mlp_gelu, init_norm, norm, unembed)

__all__ = ["init_params", "forward", "encode", "init_cache", "decode_step"]


def _sinusoids(length: int, d: int) -> jax.Array:
    """Whisper's sinusoidal position embeddings."""
    half = d // 2
    log_ts = jnp.log(10_000.0) / (half - 1)
    inv = jnp.exp(-log_ts * jnp.arange(half, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _init_enc_layer(key, cfg: ModelConfig):
    ka, kf = jax.random.split(key)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_kind),
        "attn": init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, bias=True),
        "ln2": init_norm(cfg.d_model, cfg.norm_kind),
        "mlp": init_mlp_gelu(kf, cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_kind),
        "attn": init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, bias=True),
        "lnx": init_norm(cfg.d_model, cfg.norm_kind),
        "xattn": init_attention(kx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, bias=True),
        "ln2": init_norm(cfg.d_model, cfg.norm_kind),
        "mlp": init_mlp_gelu(kf, cfg.d_model, cfg.d_ff),
    }


def init_params(cfg: ModelConfig, key: jax.Array):
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.encoder.n_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_ln": init_norm(cfg.d_model, cfg.norm_kind),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "ln_f": init_norm(cfg.d_model, cfg.norm_kind),
    }


def encode(params, cfg: ModelConfig, audio_embeds: jax.Array) -> jax.Array:
    """audio_embeds [B, T, D] (frontend-stub output) -> encoder states."""
    dt = jnp.dtype(cfg.dtype)
    x = audio_embeds.astype(dt)
    x = x + _sinusoids(x.shape[1], cfg.d_model).astype(dt)[None]

    def body(h, lp):
        u = norm(lp["ln1"], h, cfg.norm_eps)
        # bidirectional RoPE-free self attention == cross attention on u
        a = attention(lp["attn"], u, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                      causal=False, cross_kv=u)
        h = h + a
        return h + ffn(lp["mlp"], norm(lp["ln2"], h, cfg.norm_eps)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return norm(params["enc_ln"], x, cfg.norm_eps)


def _dec_block(lp, h, enc, cfg: ModelConfig, positions):
    from repro.distributed import hints
    h = hints.hint(h, hints.DATA, hints.MODEL, None)       # SP boundary
    a = attention(lp["attn"], norm(lp["ln1"], h, cfg.norm_eps),
                  n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                  head_dim=cfg.hd, positions=positions, causal=True,
                  rope_theta=cfg.rope_theta)
    h = h + a
    c = attention(lp["xattn"], norm(lp["lnx"], h, cfg.norm_eps),
                  n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                  head_dim=cfg.hd, cross_kv=enc)
    h = h + c
    return h + ffn(lp["mlp"], norm(lp["ln2"], h, cfg.norm_eps))


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            audio_embeds: jax.Array, last_only: bool = False) -> jax.Array:
    """Teacher-forced training pass: encode audio, decode tokens."""
    enc = encode(params, cfg, audio_embeds)
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, lp):
        return _dec_block(lp, h, enc, cfg, positions), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = norm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    return unembed(params["embed"], x)


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    L = cfg.n_layers
    shape = (L, batch, cfg.n_kv_heads, max_len, cfg.hd)   # head-major
    xshape = (L, batch, cfg.encoder.n_ctx, cfg.n_kv_heads, cfg.hd)
    return {
        "self": AttnCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                          False),
        # cross K/V computed once from the encoder output at prefill
        # (seq-major — consumed by the grouped helpers, written once)
        "cross_k": jnp.zeros(xshape, dtype),
        "cross_v": jnp.zeros(xshape, dtype),
    }


def prefill_cross(params, cfg: ModelConfig, enc: jax.Array):
    """Precompute per-decoder-layer cross K/V from encoder states."""
    def one(lp):
        k = dense(lp["xattn"]["wk"], enc)
        v = dense(lp["xattn"]["wv"], enc)
        sh = (*enc.shape[:-1], cfg.n_kv_heads, cfg.hd)
        return k.reshape(sh), v.reshape(sh)

    ks, vs = jax.lax.map(one, params["dec_layers"])
    return ks, vs


def decode_step(params, cfg: ModelConfig, cache, token: jax.Array,
                pos: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token[:, None], dt)

    def body(h, scanned):
        lp, c, ck, cv = scanned
        y, c2 = attn_decode(lp["attn"], norm(lp["ln1"], h, cfg.norm_eps),
                            c, pos, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                            rope_theta=cfg.rope_theta)
        h = h + y
        # cross attention against the cached encoder K/V (no mask)
        from repro.models.attention import (_gqa_out_grouped,
                                            _gqa_scores_grouped)
        u = norm(lp["lnx"], h, cfg.norm_eps)
        q = dense(lp["xattn"]["wq"], u).reshape(
            u.shape[0], 1, cfg.n_heads, cfg.hd)
        sc = _gqa_scores_grouped(q, ck.astype(dt)).astype(jnp.float32) \
            * (cfg.hd ** -0.5)
        w = jax.nn.softmax(sc, axis=-1).astype(dt)
        o = _gqa_out_grouped(w, cv.astype(dt)).reshape(
            u.shape[0], 1, cfg.n_heads * cfg.hd)
        h = h + dense(lp["xattn"]["wo"], o)
        h = h + ffn(lp["mlp"], norm(lp["ln2"], h, cfg.norm_eps))
        return h, c2

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross_k"],
                  cache["cross_v"]))
    x = norm(params["ln_f"], x, cfg.norm_eps)
    new_cache = dict(cache)
    new_cache["self"] = new_self
    return unembed(params["embed"], x)[:, 0], new_cache
