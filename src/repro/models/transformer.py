"""Decoder-only LM — dense / GQA / SWA / local:global / MoE / VLM families.

Layers are *stacked* on a leading ``L`` axis and executed with
``lax.scan`` (weights-stationary, one compiled block body regardless of
depth — 88-layer granite compiles as fast as 4-layer smoke).  Per-layer
heterogeneity (gemma3's 5 local : 1 global pattern) is data, not code: a
scanned ``window[l]`` scalar feeds the mask, so no branching is needed.

``extra_embeds`` (VLM patch embeddings / any modality frontend stub) are
prepended to the token embeddings; the frontend itself is out of scope per
the assignment (``input_specs`` supplies the embeddings).

Three entry points per family, shared cache types with the serve layer:
``init_params``, ``forward`` (train/prefill), ``decode_step``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (AttnCache, attention, attn_decode,
                                    init_attention, init_attn_cache)
from repro.models.config import ModelConfig
from repro.models.layers import (embed, ffn, init_embedding, init_mlp,
                                 init_mlp_gelu, init_norm, norm, unembed)
from repro.models.moe import init_moe, moe_ffn

__all__ = ["init_params", "forward", "init_cache", "decode_step",
           "layer_windows", "FULL_WINDOW"]

#: "no window" sentinel large enough for any assigned context (≤ 2^20).
FULL_WINDOW = 1 << 24


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def layer_windows(cfg: ModelConfig) -> Optional[tuple]:
    """Per-layer attention window (static tuple[int]) or None for pure
    full attention."""
    if cfg.local_global_ratio > 0:
        period = cfg.local_global_ratio + 1
        return tuple(cfg.sliding_window or 1024 if (l + 1) % period
                     else FULL_WINDOW for l in range(cfg.n_layers))
    if cfg.sliding_window is not None:
        return (cfg.sliding_window,) * cfg.n_layers
    return None


def _init_layer(key, cfg: ModelConfig):
    ka, kf = jax.random.split(key)
    p = {
        "ln1": init_norm(cfg.d_model, cfg.norm_kind),
        "attn": init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, bias=cfg.qkv_bias),
        "ln2": init_norm(cfg.d_model, cfg.norm_kind),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(kf, cfg.d_model, cfg.d_ff, cfg.moe)
    elif cfg.mlp_kind == "gelu":
        p["mlp"] = init_mlp_gelu(kf, cfg.d_model, cfg.d_ff)
    else:
        p["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key: jax.Array):
    ke, kl, kn = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model),
        "layers": layers,                       # stacked [L, ...]
        "ln_f": init_norm(cfg.d_model, cfg.norm_kind),
    }


def _block(lp, x, cfg: ModelConfig, *, positions, window):
    from repro.distributed import hints
    # Megatron-style sequence parallelism: the residual stream is
    # seq-sharded on `model` at block boundaries (the remat boundary
    # shrinks |model|×, which lets the 88-layer configs fit HBM); the
    # attention/FFN INPUT is explicitly re-gathered to seq-replicated so
    # GSPMD moves the ~10 MB bf16 activation, not the ~0.5 GB f32 weight
    # (observed 2.3 TB/step of full-weight gathers without this hint).
    x = hints.hint(x, hints.DATA, hints.MODEL, None)
    u = hints.hint(norm(lp["ln1"], x, cfg.norm_eps), hints.DATA, None, None)
    h = x + hints.hint(attention(
        lp["attn"], u,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        positions=positions, window=window, causal=True,
        rope_theta=cfg.rope_theta), hints.DATA, hints.MODEL, None)
    z = hints.hint(norm(lp["ln2"], h, cfg.norm_eps), hints.DATA, None, None)
    f = moe_ffn(lp["moe"], z, cfg.moe) if cfg.moe is not None \
        else ffn(lp["mlp"], z)
    return h + hints.hint(f, hints.DATA, hints.MODEL, None)


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra_embeds: Optional[jax.Array] = None,
            last_only: bool = False) -> jax.Array:
    """tokens [B, S] (+ optional prepended embeddings [B, P, D]) -> logits
    over the token positions only: [B, S, vocab].  ``last_only`` returns
    [B, 1, vocab] — serving prefill never materializes the full-sequence
    logits tensor (a 13 GB/device saving at 32k × 50k-vocab)."""
    dt = _cdtype(cfg)
    x = embed(params["embed"], tokens, dt)
    n_prefix = 0
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(dt), x], axis=1)
        n_prefix = extra_embeds.shape[1]
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    windows = layer_windows(cfg)

    def body(h, scanned):
        if windows is None:
            lp = scanned
            w = None
        else:
            lp, w = scanned
        return _block(lp, h, cfg, positions=positions, window=w), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    scanned = params["layers"] if windows is None \
        else (params["layers"], jnp.asarray(windows, jnp.int32))
    x, _ = jax.lax.scan(body_fn, x, scanned)
    x = norm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    elif n_prefix:
        x = x[:, n_prefix:]
    return unembed(params["embed"], x)


# ------------------------------------------------------------------ decode
def _stacked_cache(n_layers: int, batch: int, length: int, kv: int, hd: int,
                   ring: bool, dtype) -> AttnCache:
    shape = (n_layers, batch, kv, length, hd)     # head-major (attention.py)
    return AttnCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), ring)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Stacked per-layer KV caches.  Windowed layers get ring buffers of
    the window length — for gemma3 the mixed ring/full stack is split into
    two stacked caches (ring layers, full layers) to stay rectangular."""
    windows = layer_windows(cfg)
    if windows is None:
        return {"full": _stacked_cache(cfg.n_layers, batch, max_len,
                                       cfg.n_kv_heads, cfg.hd, False, dtype)}
    w = [int(v) for v in windows]
    ring_len = min(min([v for v in w if v < FULL_WINDOW], default=max_len),
                   max_len)
    n_ring = sum(1 for v in w if v < FULL_WINDOW)
    n_full = cfg.n_layers - n_ring
    caches = {}
    if n_ring:
        caches["ring"] = _stacked_cache(n_ring, batch, ring_len,
                                        cfg.n_kv_heads, cfg.hd, True, dtype)
    if n_full:
        caches["full"] = _stacked_cache(n_full, batch, max_len,
                                        cfg.n_kv_heads, cfg.hd, False, dtype)
    return caches


def decode_step(params, cfg: ModelConfig, cache, token: jax.Array,
                pos: jax.Array):
    """One decode step.  token [B] int32; pos scalar.  Returns
    (logits [B, vocab], new cache)."""
    dt = _cdtype(cfg)
    x = embed(params["embed"], token[:, None], dt)     # [B, 1, D]
    windows = layer_windows(cfg)

    if windows is None:
        # The cache is updated through the scan CARRY (slice layer l,
        # update, write back) rather than ys stacking: XLA:CPU materializes
        # bf16 ys accumulators in f32 (2× the whole cache); the carry form
        # keeps the buffer at its own dtype and donates cleanly.
        def body(carry, scanned):
            h, cc = carry
            lp, idx = scanned
            c = jax.tree_util.tree_map(lambda a: a[idx], cc)
            y, c2 = attn_decode(
                lp["attn"], norm(lp["ln1"], h, cfg.norm_eps), c, pos,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd, window=None, rope_theta=cfg.rope_theta)
            cc = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0), cc, c2)
            h = h + y
            z = norm(lp["ln2"], h, cfg.norm_eps)
            f = moe_ffn(lp["moe"], z, cfg.moe) if cfg.moe is not None \
                else ffn(lp["mlp"], z)
            return (h + f, cc), None

        (x, new_full), _ = jax.lax.scan(
            body, (x, cache["full"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"full": new_full}
    elif all(int(v) < FULL_WINDOW for v in windows) and len(
            set(int(v) for v in windows)) == 1:
        # Uniform SWA stack (h2o-danube): carry-updated ring caches.
        win = int(windows[0])

        def body(carry, scanned):
            h, cc = carry
            lp, idx = scanned
            c = jax.tree_util.tree_map(lambda a: a[idx], cc)
            y, c2 = attn_decode(
                lp["attn"], norm(lp["ln1"], h, cfg.norm_eps), c, pos,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd, window=win, rope_theta=cfg.rope_theta)
            cc = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0), cc, c2)
            h = h + y
            z = norm(lp["ln2"], h, cfg.norm_eps)
            f = moe_ffn(lp["moe"], z, cfg.moe) if cfg.moe is not None \
                else ffn(lp["mlp"], z)
            return (h + f, cc), None

        (x, new_ring), _ = jax.lax.scan(
            body, (x, cache["ring"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"ring": new_ring}
    else:
        # Mixed local:global (gemma3): unrolled pass indexing the right
        # stack per layer (26 layers — acceptable unroll).
        w = [int(v) for v in windows]
        ring_ids = [l for l, v in enumerate(w) if v < FULL_WINDOW]
        full_ids = [l for l, v in enumerate(w) if v >= FULL_WINDOW]
        new_ring = cache.get("ring")
        new_full = cache.get("full")
        h = x
        ring_pos = {l: i for i, l in enumerate(ring_ids)}
        full_pos = {l: i for i, l in enumerate(full_ids)}
        for l in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[l], params["layers"])
            if l in ring_pos:
                i = ring_pos[l]
                c = jax.tree_util.tree_map(lambda a: a[i], new_ring)
                win = w[l]
            else:
                i = full_pos[l]
                c = jax.tree_util.tree_map(lambda a: a[i], new_full)
                win = None
            y, c2 = attn_decode(
                lp["attn"], norm(lp["ln1"], h, cfg.norm_eps), c, pos,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd, window=win, rope_theta=cfg.rope_theta)
            h = h + y
            z = norm(lp["ln2"], h, cfg.norm_eps)
            f = moe_ffn(lp["moe"], z, cfg.moe) if cfg.moe is not None \
                else ffn(lp["mlp"], z)
            h = h + f
            upd = lambda a, u, i=i: a.at[i].set(u)
            if l in ring_pos:
                new_ring = jax.tree_util.tree_map(upd, new_ring, c2)
            else:
                new_full = jax.tree_util.tree_map(upd, new_full, c2)
        x = h
        new_cache = {}
        if new_ring is not None:
            new_cache["ring"] = new_ring
        if new_full is not None:
            new_cache["full"] = new_full

    x = norm(params["ln_f"], x, cfg.norm_eps)
    return unembed(params["embed"], x)[:, 0], new_cache
