"""Family-dispatching model API — the single surface the trainer, server,
and dry-run consume.

``batch`` dicts (produced by ``configs.input_specs``):
  * LM families:    {"tokens": [B,S] i32, "labels": [B,S] i32}
  * vlm:            + {"patch_embeds": [B,P,D]}
  * audio (encdec): + {"audio_embeds": [B,T,D]}
  * decode shapes:  {"token": [B] i32, "pos": scalar i32, "cache": ...}
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer
from repro.models.config import ModelConfig

__all__ = ["init_params", "forward_logits", "loss_fn", "init_cache",
           "decode_step", "count_params"]


def _mod(cfg: ModelConfig):
    if cfg.family in ("ssm", "hybrid"):
        return hybrid
    if cfg.encoder is not None:
        return encdec
    return transformer


def init_params(cfg: ModelConfig, key: jax.Array):
    return _mod(cfg).init_params(cfg, key)


def forward_logits(params, cfg: ModelConfig, batch: Dict[str, Any],
                   last_only: bool = False):
    tokens = batch["tokens"]
    if cfg.encoder is not None:
        return encdec.forward(params, cfg, tokens, batch["audio_embeds"],
                              last_only=last_only)
    extra = batch.get("patch_embeds")
    return _mod(cfg).forward(params, cfg, tokens, extra_embeds=extra,
                             last_only=last_only)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any]) -> jax.Array:
    """Mean next-token cross entropy (fp32 logits)."""
    logits = forward_logits(params, cfg, batch)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return _mod(cfg).init_cache(cfg, batch, max_len, dtype)


def decode_step(params, cfg: ModelConfig, cache, token: jax.Array,
                pos: jax.Array):
    return _mod(cfg).decode_step(params, cfg, cache, token, pos)


def count_params(params) -> int:
    return sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))
