"""Model configuration — one dataclass covering all ten assigned families.

A single ``ModelConfig`` describes dense / GQA / SWA / local-global / MoE /
SSM / hybrid / encoder-decoder / frontend-stub architectures; family-specific
fields are ``None``/0 when unused.  ``reduced()`` derives the small
same-family config used by the CPU smoke tests (the full config is only ever
lowered via ShapeDtypeStructs in the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "EncoderConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    d_conv: int = 4
    headdim: int = 64
    chunk: int = 256         # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper) — frontend is a stub:
    ``input_specs`` supplies precomputed frame/patch embeddings."""
    n_layers: int
    n_ctx: int               # encoder positions (1500 audio frames / patches)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default d_model // n_heads
    qkv_bias: bool = False               # qwen2.5
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # --- attention pattern -------------------------------------------------
    sliding_window: Optional[int] = None     # SWA width (h2o-danube)
    local_global_ratio: int = 0              # gemma3: N local per 1 global
    # --- mixture of experts -------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- state space --------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0      # hybrid (zamba2): shared attn every k layers
    # --- encoder-decoder / multimodal frontend stubs ------------------------
    encoder: Optional[EncoderConfig] = None
    n_patches: int = 0       # vlm: patch embeddings prepended to the sequence
    # --- block flavor --------------------------------------------------------
    norm_kind: str = "rms"       # "rms" | "ln" (whisper)
    mlp_kind: str = "swiglu"     # "swiglu" | "gelu" (whisper)
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True       # checkpoint each block in the train step

    # -------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or windowed attention."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.local_global_ratio > 0)

    @property
    def has_decoder(self) -> bool:
        return True          # all assigned archs decode (whisper: decoder side)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, L, hd = self.d_model, self.d_ff, self.n_layers, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + self.n_heads * hd * d
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_ssm_heads(d)
            ssm_blk = d * (2 * di + 2 * self.ssm.d_state + nh) + di * d \
                + self.ssm.d_conv * (di + 2 * self.ssm.d_state) + 2 * nh
        else:
            ssm_blk = 0
        n_mats = 2 if self.mlp_kind == "gelu" else 3
        if self.moe is not None:
            ffn = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
        else:
            ffn = n_mats * d * f
        if self.family == "ssm":
            blocks = L * (ssm_blk + d)
        elif self.family == "hybrid":
            n_attn = L // max(self.attn_every, 1)
            blocks = L * (ssm_blk + d) + (attn + 3 * d * f + 2 * d)  # shared
            blocks += 0 * n_attn
        else:
            blocks = L * (attn + ffn + 2 * d)
        if self.encoder is not None:
            blocks += self.encoder.n_layers * (2 * attn + 3 * d * f + 3 * d)
            blocks += L * attn               # decoder cross-attention
        return emb + blocks + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts) — the N in
        MODEL_FLOPS = 6·N·D."""
        if self.moe is None:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        all_experts = L * self.moe.n_experts * 3 * d * f
        active = L * self.moe.top_k * 3 * d * f
        return self.param_count() - all_experts + active

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.attn_every == 0
                         else 2 * self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=256,
            vocab=512,
            head_dim=32,
            sliding_window=64 if self.sliding_window else None,
            moe=(dataclasses.replace(self.moe, n_experts=min(
                self.moe.n_experts, 8), top_k=min(self.moe.top_k, 2))
                if self.moe else None),
            ssm=(dataclasses.replace(self.ssm, d_state=16, headdim=32,
                                     chunk=32) if self.ssm else None),
            encoder=(dataclasses.replace(self.encoder, n_layers=2, n_ctx=64)
                     if self.encoder else None),
            n_patches=16 if self.n_patches else 0,
            dtype="float32",
            remat=False,
        )
