"""LM model substrate — pure JAX, scan-over-layers, shard-friendly.

Families: dense/GQA/SWA/local-global/MoE (:mod:`transformer`),
SSM + hybrid (:mod:`hybrid`), encoder-decoder (:mod:`encdec`).
Dispatch through :mod:`repro.models.api`.
"""
from repro.models.api import (count_params, decode_step, forward_logits,
                              init_cache, init_params, loss_fn)
from repro.models.config import (EncoderConfig, ModelConfig, MoEConfig,
                                 SSMConfig)

__all__ = ["init_params", "forward_logits", "loss_fn", "init_cache",
           "decode_step", "count_params", "ModelConfig", "MoEConfig",
           "SSMConfig", "EncoderConfig"]
