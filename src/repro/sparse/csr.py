"""Host-side CSR container and reference operations.

CSR is the framework's interchange format: loaders and generators produce CSR,
and the TPU-facing banked-ELL format (:mod:`repro.sparse.bell`) is derived
from it.  Arrays are kept as numpy on the host; device placement happens when
a solver/kernel consumes them.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["CSRMatrix", "csr_from_coo", "csr_to_dense", "csr_spmv"]


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Compressed-sparse-row matrix (host-side, numpy arrays)."""

    indptr: np.ndarray   # int64[n_rows + 1]
    indices: np.ndarray  # int32[nnz] column indices, sorted within a row
    data: np.ndarray     # value dtype [nnz]
    shape: Tuple[int, int]

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def dtype(self):
        return self.data.dtype

    def diagonal(self) -> np.ndarray:
        """Extract the main diagonal (the Jacobi preconditioner source)."""
        n = min(self.shape)
        diag = np.zeros(n, dtype=self.data.dtype)
        row_ids = np.repeat(np.arange(self.n_rows, dtype=np.int64), self.row_nnz())
        mask = (self.indices == row_ids) & (row_ids < n)
        diag[row_ids[mask]] = self.data[mask]
        return diag

    def astype(self, dtype) -> "CSRMatrix":
        return CSRMatrix(self.indptr, self.indices, self.data.astype(dtype), self.shape)

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def is_symmetric(self, tol: float = 0.0) -> bool:
        """Structural + value symmetry check (dense fallback for small n)."""
        if self.n_rows != self.n_cols:
            return False
        if self.n_rows <= 4096:
            d = csr_to_dense(self)
            return bool(np.allclose(d, d.T, atol=tol, rtol=0.0))
        # sampled check for large matrices
        rng = np.random.default_rng(0)
        rows = rng.integers(0, self.n_rows, size=512)
        for i in rows:
            for k in range(self.indptr[i], self.indptr[i + 1]):
                j = self.indices[k]
                v = self.data[k]
                row_j = slice(self.indptr[j], self.indptr[j + 1])
                hit = np.searchsorted(self.indices[row_j], i)
                base = self.indptr[j] + hit
                if hit >= self.indptr[j + 1] - self.indptr[j] or self.indices[base] != i:
                    return False
                if abs(self.data[base] - v) > tol:
                    return False
        return True


def csr_from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 shape: Tuple[int, int], sum_duplicates: bool = True) -> CSRMatrix:
    """Build CSR from COO triplets (duplicates summed, rows sorted)."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and rows.size:
        key_change = np.empty(rows.shape[0], dtype=bool)
        key_change[0] = True
        key_change[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        group = np.cumsum(key_change) - 1
        uniq = int(group[-1]) + 1
        new_vals = np.zeros(uniq, dtype=vals.dtype)
        np.add.at(new_vals, group, vals)
        rows = rows[key_change]
        cols = cols[key_change]
        vals = new_vals
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRMatrix(indptr=indptr, indices=cols.astype(np.int32), data=vals, shape=shape)


def csr_to_dense(a: CSRMatrix) -> np.ndarray:
    out = np.zeros(a.shape, dtype=a.data.dtype)
    row_ids = np.repeat(np.arange(a.n_rows, dtype=np.int64), a.row_nnz())
    out[row_ids, a.indices] = a.data
    return out


def csr_spmv(a: CSRMatrix, x: np.ndarray) -> np.ndarray:
    """Reference SpMV (numpy; fp64 accumulation via bincount)."""
    acc_dtype = np.result_type(a.data.dtype, x.dtype)
    row_ids = np.repeat(np.arange(a.n_rows, dtype=np.int64), a.row_nnz())
    prod = a.data.astype(np.float64) * x[a.indices].astype(np.float64)
    out = np.bincount(row_ids, weights=prod, minlength=a.n_rows)
    return out.astype(acc_dtype)
