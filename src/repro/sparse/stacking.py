"""Batched padding / stacking of sparse layouts (the multi-system path).

The batched JPCG engine (:mod:`repro.core.batch`) solves B independent
systems inside ONE compiled ``lax.while_loop``.  That requires every
lane's matrix to share one padded shape, so the per-lane layouts are

1. **bucketed** — each structural dimension (row blocks, slabs, slab
   length, col tiles) is rounded up to a bucket edge (next power of two
   by default) so heterogeneous traffic collapses onto a handful of
   compiled executables (the paper's "arbitrary problem without
   re-synthesis" goal, batched); and
2. **zero-padded + stacked** along a new leading batch axis.

Padding entries carry ``val = 0`` and local indices ``0``: they
contribute ``0 * x[tile_base]`` to row ``block_base`` — harmless for the
flat-slab :class:`~repro.sparse.bell.BellMatrix` (scatter-add of zeros)
and for the slot-major :class:`~repro.sparse.ellpack.EllpackMatrix`
(vectorized add of zeros) alike.  Padded *rows* are handled by the
caller giving them a unit diagonal and zero rhs, so their residual is
identically zero and they never influence termination.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.sparse.bell import BellMatrix
from repro.sparse.ellpack import EllpackMatrix

__all__ = ["bucket_up", "pad_bell", "stack_bell", "pad_ellpack",
           "stack_ellpack", "flatten_bell", "stack_flat", "csr_rowell",
           "stack_rowell", "StackedBell", "StackedEllpack", "StackedFlat",
           "StackedRowEll"]


def bucket_up(x: int, *, minimum: int = 1) -> int:
    """Round ``x`` up to the next bucket edge (powers of two).

    Bucket edges bound the number of distinct compiled shapes by
    ``O(log max_size)`` per dimension — the compile-cache policy of the
    batched solver.
    """
    x = max(int(x), minimum)
    return 1 << (x - 1).bit_length()


def _pad_axis(a: np.ndarray, axis: int, size: int) -> np.ndarray:
    if a.shape[axis] == size:
        return a
    if a.shape[axis] > size:
        raise ValueError(f"cannot shrink axis {axis}: {a.shape[axis]} > {size}")
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, size - a.shape[axis])
    return np.pad(a, widths)


def pad_bell(m: BellMatrix, *, n_row_blocks: int, n_slabs: int,
             slab_len: int) -> BellMatrix:
    """Zero-pad a flat-slab banked-ELL matrix to the given structural dims."""
    def pad3(a):
        a = _pad_axis(a, 0, n_row_blocks)
        a = _pad_axis(a, 1, n_slabs)
        return _pad_axis(a, 2, slab_len)

    return dataclasses.replace(
        m,
        tile_cols=_pad_axis(_pad_axis(m.tile_cols, 0, n_row_blocks), 1, n_slabs),
        vals=pad3(m.vals),
        local_rows=pad3(m.local_rows),
        local_cols=pad3(m.local_cols))


def pad_ellpack(m: EllpackMatrix, *, n_row_blocks: int, n_slabs: int,
                ell: int) -> EllpackMatrix:
    """Zero-pad a slot-major ELLPACK matrix to the given structural dims."""
    def pad4(a):
        a = _pad_axis(a, 0, n_row_blocks)
        a = _pad_axis(a, 1, n_slabs)
        return _pad_axis(a, 2, ell)

    return dataclasses.replace(
        m,
        tile_cols=_pad_axis(_pad_axis(m.tile_cols, 0, n_row_blocks), 1, n_slabs),
        vals=pad4(m.vals),
        local_cols=pad4(m.local_cols))


@dataclasses.dataclass(frozen=True)
class StackedBell:
    """B flat-slab banked-ELL matrices padded to one shape, stacked on axis 0."""

    tile_cols: np.ndarray   # int32[G, B, T]
    vals: np.ndarray        # v[G, B, T, L]
    local_rows: np.ndarray  # int32[G, B, T, L]
    local_cols: np.ndarray  # int32[G, B, T, L]
    shapes: Tuple[Tuple[int, int], ...]   # logical per-lane shapes
    nnzs: Tuple[int, ...]
    block_rows: int
    col_tile: int
    n_col_tiles: int        # shared padded x-tile count

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.vals.shape[1]) * self.block_rows

    @property
    def padded_cols(self) -> int:
        return self.n_col_tiles * self.col_tile


@dataclasses.dataclass(frozen=True)
class StackedEllpack:
    """B slot-major ELLPACK matrices padded to one shape, stacked on axis 0."""

    tile_cols: np.ndarray   # int32[G, B, T]
    vals: np.ndarray        # v[G, B, T, E, R]
    local_cols: np.ndarray  # int32[G, B, T, E, R]
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]
    block_rows: int
    col_tile: int
    n_col_tiles: int

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.vals.shape[1]) * self.block_rows

    @property
    def padded_cols(self) -> int:
        return self.n_col_tiles * self.col_tile


def stack_bell(mats: Sequence[BellMatrix], *, bucket: bool = True) -> StackedBell:
    """Pad a heterogeneous list of BellMatrix to one (bucketed) shape and stack.

    All inputs must share ``block_rows``/``col_tile`` (they parameterize
    the kernel, not the problem).  With ``bucket=True`` every structural
    dim is rounded up to a power-of-two edge so different batches of
    similar problems reuse the same compiled solver.
    """
    if not mats:
        raise ValueError("stack_bell needs at least one matrix")
    r, c = mats[0].block_rows, mats[0].col_tile
    for m in mats:
        if (m.block_rows, m.col_tile) != (r, c):
            raise ValueError("all matrices must share block_rows/col_tile")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    B = rnd(max(m.n_row_blocks for m in mats))
    T = rnd(max(m.n_slabs for m in mats))
    L = rnd(max(m.slab_len for m in mats))
    n_tiles = rnd(max(m.n_col_tiles for m in mats))
    padded = [pad_bell(m, n_row_blocks=B, n_slabs=T, slab_len=L) for m in mats]
    return StackedBell(
        tile_cols=np.stack([m.tile_cols for m in padded]),
        vals=np.stack([m.vals for m in padded]),
        local_rows=np.stack([m.local_rows for m in padded]),
        local_cols=np.stack([m.local_cols for m in padded]),
        shapes=tuple(m.shape for m in mats),
        nnzs=tuple(m.nnz for m in mats),
        block_rows=r, col_tile=c, n_col_tiles=n_tiles)


def stack_ellpack(mats: Sequence[EllpackMatrix], *,
                  bucket: bool = True) -> StackedEllpack:
    """Pad a heterogeneous list of EllpackMatrix to one shape and stack.

    The slot-major twin of :func:`stack_bell` — feeds the batched Pallas
    SpMV grid (:func:`repro.kernels.spmv.spmv_pallas_batched`).
    """
    if not mats:
        raise ValueError("stack_ellpack needs at least one matrix")
    r, c = mats[0].block_rows, mats[0].col_tile
    for m in mats:
        if (m.block_rows, m.col_tile) != (r, c):
            raise ValueError("all matrices must share block_rows/col_tile")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    B = rnd(max(m.n_row_blocks for m in mats))
    T = rnd(max(m.n_slabs for m in mats))
    E = rnd(max(m.ell for m in mats))
    n_tiles = rnd(max(m.n_col_tiles for m in mats))
    padded = [pad_ellpack(m, n_row_blocks=B, n_slabs=T, ell=E) for m in mats]
    return StackedEllpack(
        tile_cols=np.stack([m.tile_cols for m in padded]),
        vals=np.stack([m.vals for m in padded]),
        local_cols=np.stack([m.local_cols for m in padded]),
        shapes=tuple(m.shape for m in mats),
        nnzs=tuple(m.nnz for m in mats),
        block_rows=r, col_tile=c, n_col_tiles=n_tiles)


def flatten_bell(m: BellMatrix):
    """Flatten a banked-ELL matrix to its packed nonzero stream.

    Returns ``(global_cols, vals, rows)`` int32/value/int32 1-D arrays —
    the closest host-side analogue of the Serpens/Callipepla per-channel
    packed (col, row, val) stream.  Padding entries carry
    ``(0, 0.0, 0)``: they add ``0 · x[0]`` to row 0, so a flat stream
    can be zero-extended to ANY length without changing the product —
    which is why the batched XLA solver buckets only this one dimension.
    """
    C, R = m.col_tile, m.block_rows
    gcols = (m.tile_cols[:, :, None] * C + m.local_cols).reshape(-1)
    blk = np.arange(m.n_row_blocks, dtype=np.int64)[:, None, None]
    rows = (blk * R + m.local_rows).reshape(-1)
    return (gcols.astype(np.int32), m.vals.reshape(-1).copy(),
            rows.astype(np.int32))


@dataclasses.dataclass(frozen=True)
class StackedFlat:
    """B packed nonzero streams padded to one length, stacked on axis 0.

    The batched XLA solver's matrix operand: bucketing the *stream
    length* (one dimension) instead of (row blocks × slabs × slab len)
    independently keeps padding waste ≤ 2× per lane where the 3-D
    bucket compounds to ~8×.
    """

    gcols: np.ndarray       # int32[G, N] global column per nonzero
    vals: np.ndarray        # v[G, N]
    rows: np.ndarray        # int32[G, N] global (padded) row per nonzero
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]
    block_rows: int
    col_tile: int
    n_row_blocks: int       # shared (bucketed) row-block count
    n_col_tiles: int

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return self.n_row_blocks * self.block_rows

    @property
    def padded_cols(self) -> int:
        return self.n_col_tiles * self.col_tile


def stack_flat(mats: Sequence[BellMatrix], *, bucket: bool = True) -> StackedFlat:
    """Flatten + pad + stack banked-ELL matrices as packed nonzero streams."""
    if not mats:
        raise ValueError("stack_flat needs at least one matrix")
    r, c = mats[0].block_rows, mats[0].col_tile
    for m in mats:
        if (m.block_rows, m.col_tile) != (r, c):
            raise ValueError("all matrices must share block_rows/col_tile")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    flats = [flatten_bell(m) for m in mats]
    N = rnd(max(f[0].shape[0] for f in flats))
    B = rnd(max(m.n_row_blocks for m in mats))
    n_tiles = rnd(max(m.n_col_tiles for m in mats))
    G = len(mats)
    gcols = np.zeros((G, N), np.int32)
    vals = np.zeros((G, N), mats[0].vals.dtype)
    rows = np.zeros((G, N), np.int32)
    for g, (gc, v, rw) in enumerate(flats):
        gcols[g, : gc.shape[0]] = gc
        vals[g, : v.shape[0]] = v
        rows[g, : rw.shape[0]] = rw
    return StackedFlat(gcols, vals, rows,
                       shapes=tuple(m.shape for m in mats),
                       nnzs=tuple(m.nnz for m in mats),
                       block_rows=r, col_tile=c, n_row_blocks=B,
                       n_col_tiles=n_tiles)


# ---------------------------------------------------------- row-major ELL
def csr_rowell(a) -> Tuple[np.ndarray, np.ndarray]:
    """Row-major ELL arrays ``(cols int32[n, W], vals[n, W])`` from CSR.

    ``W`` = max nonzeros per row (≥ 1); short rows are padded with
    ``(col 0, val 0)`` entries, which contribute ``0 · x[0]`` — harmless.
    Entries keep their CSR (sorted-column) order within a row, so the
    SpMV accumulation order is deterministic per row.

    This is the *scatter-free* batched layout: ``y[i] = Σ_w vals[i, w] ·
    x[cols[i, w]]`` is a gather + a dense reduction over the width axis,
    where the packed-stream layout (:func:`flatten_bell` /
    :func:`stack_flat`) needs a segment-sum **scatter** per nonzero —
    ~100 ns/element on XLA CPU, which made the batched solver lose to
    the one-at-a-time python loop by ~30× before the layout switch.
    """
    n = a.shape[0]
    rn = np.asarray(a.row_nnz(), np.int64)
    W = max(int(rn.max()) if n else 0, 1)
    cols = np.zeros((n, W), np.int32)
    vals = np.zeros((n, W), a.data.dtype)
    if a.nnz:
        idx = a.indptr[:-1, None] + np.arange(W, dtype=np.int64)[None, :]
        mask = np.arange(W)[None, :] < rn[:, None]
        safe = np.clip(idx, 0, a.nnz - 1)
        cols = np.where(mask, a.indices[safe], 0).astype(np.int32)
        vals = np.where(mask, a.data[safe], 0)
    return cols, vals


@dataclasses.dataclass(frozen=True)
class StackedRowEll:
    """B row-major ELL matrices padded to one ``(n_pad, W)`` shape and
    stacked on axis 0 — the batched XLA solver's matrix operand.

    Padded rows (beyond a lane's logical ``n``) are all-zero: they
    produce ``y = 0`` and the caller gives them unit diagonal / zero rhs
    so they never influence termination.  Both dims are bucketed
    (power-of-two edges), so the executable cache stays ``O(log n ·
    log nnz_row)``.
    """

    cols: np.ndarray        # int32[G, n_pad, W] column index per slot
    vals: np.ndarray        # v[G, n_pad, W]
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.vals.shape[1])

    @property
    def width(self) -> int:
        return int(self.vals.shape[2])


def stack_rowell(csrs: Sequence, *, bucket: bool = True) -> StackedRowEll:
    """Pad a heterogeneous list of CSR matrices to one row-ELL shape and
    stack along a new leading batch axis (see :func:`csr_rowell`)."""
    if not csrs:
        raise ValueError("stack_rowell needs at least one matrix")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    lanes = [csr_rowell(a) for a in csrs]
    n_pad = rnd(max(a.shape[0] for a in csrs))
    W = rnd(max(c.shape[1] for c, _ in lanes))
    G = len(csrs)
    cols = np.zeros((G, n_pad, W), np.int32)
    vals = np.zeros((G, n_pad, W), lanes[0][1].dtype)
    for g, (c, v) in enumerate(lanes):
        cols[g, : c.shape[0], : c.shape[1]] = c
        vals[g, : v.shape[0], : v.shape[1]] = v
    return StackedRowEll(cols, vals,
                         shapes=tuple(a.shape for a in csrs),
                         nnzs=tuple(a.nnz for a in csrs))
