"""Batched padding / stacking of sparse layouts (the multi-system path).

The batched JPCG engine (:mod:`repro.core.batch`) solves B independent
systems inside ONE compiled ``lax.while_loop``.  That requires every
lane's matrix to share one padded shape, so the per-lane layouts are

1. **bucketed** — each structural dimension (row blocks, slabs, slab
   length, col tiles) is rounded up to a bucket edge (next power of two
   by default) so heterogeneous traffic collapses onto a handful of
   compiled executables (the paper's "arbitrary problem without
   re-synthesis" goal, batched); and
2. **zero-padded + stacked** along a new leading batch axis.

Padding entries carry ``val = 0`` and local indices ``0``: they
contribute ``0 * x[tile_base]`` to row ``block_base`` — harmless for the
flat-slab :class:`~repro.sparse.bell.BellMatrix` (scatter-add of zeros)
and for the slot-major :class:`~repro.sparse.ellpack.EllpackMatrix`
(vectorized add of zeros) alike.  Padded *rows* are handled by the
caller giving them a unit diagonal and zero rhs, so their residual is
identically zero and they never influence termination.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.sparse.bell import BellMatrix
from repro.sparse.ellpack import EllpackMatrix

__all__ = ["bucket_up", "lane_bucket_up", "pad_bell", "stack_bell",
           "pad_ellpack",
           "stack_ellpack", "flatten_bell", "stack_flat", "csr_rowell",
           "stack_rowell", "stack_sell", "StackedBell", "StackedEllpack",
           "StackedFlat", "StackedRowEll", "StackedSell",
           "sell_slice_widths", "index_dtype",
           "index_bytes_for", "rowell_padding_ratio", "choose_layout",
           "SELL_PADDING_THRESHOLD", "SELL_SLICE_ROWS"]


def bucket_up(x: int, *, minimum: int = 1) -> int:
    """Round ``x`` up to the next bucket edge (powers of two).

    Bucket edges bound the number of distinct compiled shapes by
    ``O(log max_size)`` per dimension — the compile-cache policy of the
    batched solver.
    """
    x = max(int(x), minimum)
    return 1 << (x - 1).bit_length()


def lane_bucket_up(x: int, *, parts: int = 1, minimum: int = 1) -> int:
    """Round a *lane* count up to a bucket edge that ``parts`` shards
    divide evenly.

    The lane-sharded serving pool (:mod:`repro.core.shard`) partitions
    the lane axis over D devices with ``NamedSharding``, which requires
    the axis to divide by D — so its lane buckets are the power-of-two
    edges of :func:`bucket_up` rounded up to a multiple of ``parts``.
    ``parts=1`` degenerates to :func:`bucket_up` exactly (the
    single-device pool's lane policy, unchanged).
    """
    t = bucket_up(x, minimum=minimum)
    parts = max(int(parts), 1)
    if parts > 1:
        t = -(-t // parts) * parts
    return t


def _pad_axis(a: np.ndarray, axis: int, size: int) -> np.ndarray:
    if a.shape[axis] == size:
        return a
    if a.shape[axis] > size:
        raise ValueError(f"cannot shrink axis {axis}: {a.shape[axis]} > {size}")
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, size - a.shape[axis])
    return np.pad(a, widths)


def pad_bell(m: BellMatrix, *, n_row_blocks: int, n_slabs: int,
             slab_len: int) -> BellMatrix:
    """Zero-pad a flat-slab banked-ELL matrix to the given structural dims."""
    def pad3(a):
        a = _pad_axis(a, 0, n_row_blocks)
        a = _pad_axis(a, 1, n_slabs)
        return _pad_axis(a, 2, slab_len)

    return dataclasses.replace(
        m,
        tile_cols=_pad_axis(_pad_axis(m.tile_cols, 0, n_row_blocks), 1, n_slabs),
        vals=pad3(m.vals),
        local_rows=pad3(m.local_rows),
        local_cols=pad3(m.local_cols))


def pad_ellpack(m: EllpackMatrix, *, n_row_blocks: int, n_slabs: int,
                ell: int) -> EllpackMatrix:
    """Zero-pad a slot-major ELLPACK matrix to the given structural dims."""
    def pad4(a):
        a = _pad_axis(a, 0, n_row_blocks)
        a = _pad_axis(a, 1, n_slabs)
        return _pad_axis(a, 2, ell)

    return dataclasses.replace(
        m,
        tile_cols=_pad_axis(_pad_axis(m.tile_cols, 0, n_row_blocks), 1, n_slabs),
        vals=pad4(m.vals),
        local_cols=pad4(m.local_cols))


@dataclasses.dataclass(frozen=True)
class StackedBell:
    """B flat-slab banked-ELL matrices padded to one shape, stacked on axis 0."""

    tile_cols: np.ndarray   # int32[G, B, T]
    vals: np.ndarray        # v[G, B, T, L]
    local_rows: np.ndarray  # int32[G, B, T, L]
    local_cols: np.ndarray  # int32[G, B, T, L]
    shapes: Tuple[Tuple[int, int], ...]   # logical per-lane shapes
    nnzs: Tuple[int, ...]
    block_rows: int
    col_tile: int
    n_col_tiles: int        # shared padded x-tile count

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.vals.shape[1]) * self.block_rows

    @property
    def padded_cols(self) -> int:
        return self.n_col_tiles * self.col_tile


@dataclasses.dataclass(frozen=True)
class StackedEllpack:
    """B slot-major ELLPACK matrices padded to one shape, stacked on axis 0."""

    tile_cols: np.ndarray   # int32[G, B, T]
    vals: np.ndarray        # v[G, B, T, E, R]
    local_cols: np.ndarray  # int32[G, B, T, E, R]
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]
    block_rows: int
    col_tile: int
    n_col_tiles: int

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.vals.shape[1]) * self.block_rows

    @property
    def padded_cols(self) -> int:
        return self.n_col_tiles * self.col_tile


def stack_bell(mats: Sequence[BellMatrix], *, bucket: bool = True) -> StackedBell:
    """Pad a heterogeneous list of BellMatrix to one (bucketed) shape and stack.

    All inputs must share ``block_rows``/``col_tile`` (they parameterize
    the kernel, not the problem).  With ``bucket=True`` every structural
    dim is rounded up to a power-of-two edge so different batches of
    similar problems reuse the same compiled solver.
    """
    if not mats:
        raise ValueError("stack_bell needs at least one matrix")
    r, c = mats[0].block_rows, mats[0].col_tile
    for m in mats:
        if (m.block_rows, m.col_tile) != (r, c):
            raise ValueError("all matrices must share block_rows/col_tile")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    B = rnd(max(m.n_row_blocks for m in mats))
    T = rnd(max(m.n_slabs for m in mats))
    L = rnd(max(m.slab_len for m in mats))
    n_tiles = rnd(max(m.n_col_tiles for m in mats))
    padded = [pad_bell(m, n_row_blocks=B, n_slabs=T, slab_len=L) for m in mats]
    return StackedBell(
        tile_cols=np.stack([m.tile_cols for m in padded]),
        vals=np.stack([m.vals for m in padded]),
        local_rows=np.stack([m.local_rows for m in padded]),
        local_cols=np.stack([m.local_cols for m in padded]),
        shapes=tuple(m.shape for m in mats),
        nnzs=tuple(m.nnz for m in mats),
        block_rows=r, col_tile=c, n_col_tiles=n_tiles)


def stack_ellpack(mats: Sequence[EllpackMatrix], *,
                  bucket: bool = True) -> StackedEllpack:
    """Pad a heterogeneous list of EllpackMatrix to one shape and stack.

    The slot-major twin of :func:`stack_bell` — feeds the batched Pallas
    SpMV grid (:func:`repro.kernels.spmv.spmv_pallas_batched`).
    """
    if not mats:
        raise ValueError("stack_ellpack needs at least one matrix")
    r, c = mats[0].block_rows, mats[0].col_tile
    for m in mats:
        if (m.block_rows, m.col_tile) != (r, c):
            raise ValueError("all matrices must share block_rows/col_tile")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    B = rnd(max(m.n_row_blocks for m in mats))
    T = rnd(max(m.n_slabs for m in mats))
    E = rnd(max(m.ell for m in mats))
    n_tiles = rnd(max(m.n_col_tiles for m in mats))
    padded = [pad_ellpack(m, n_row_blocks=B, n_slabs=T, ell=E) for m in mats]
    return StackedEllpack(
        tile_cols=np.stack([m.tile_cols for m in padded]),
        vals=np.stack([m.vals for m in padded]),
        local_cols=np.stack([m.local_cols for m in padded]),
        shapes=tuple(m.shape for m in mats),
        nnzs=tuple(m.nnz for m in mats),
        block_rows=r, col_tile=c, n_col_tiles=n_tiles)


def flatten_bell(m: BellMatrix):
    """Flatten a banked-ELL matrix to its packed nonzero stream.

    Returns ``(global_cols, vals, rows)`` int32/value/int32 1-D arrays —
    the closest host-side analogue of the Serpens/Callipepla per-channel
    packed (col, row, val) stream.  Padding entries carry
    ``(0, 0.0, 0)``: they add ``0 · x[0]`` to row 0, so a flat stream
    can be zero-extended to ANY length without changing the product —
    which is why the batched XLA solver buckets only this one dimension.
    """
    C, R = m.col_tile, m.block_rows
    gcols = (m.tile_cols[:, :, None] * C + m.local_cols).reshape(-1)
    blk = np.arange(m.n_row_blocks, dtype=np.int64)[:, None, None]
    rows = (blk * R + m.local_rows).reshape(-1)
    return (gcols.astype(np.int32), m.vals.reshape(-1).copy(),
            rows.astype(np.int32))


@dataclasses.dataclass(frozen=True)
class StackedFlat:
    """B packed nonzero streams padded to one length, stacked on axis 0.

    The batched XLA solver's matrix operand: bucketing the *stream
    length* (one dimension) instead of (row blocks × slabs × slab len)
    independently keeps padding waste ≤ 2× per lane where the 3-D
    bucket compounds to ~8×.
    """

    gcols: np.ndarray       # int32[G, N] global column per nonzero
    vals: np.ndarray        # v[G, N]
    rows: np.ndarray        # int32[G, N] global (padded) row per nonzero
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]
    block_rows: int
    col_tile: int
    n_row_blocks: int       # shared (bucketed) row-block count
    n_col_tiles: int

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return self.n_row_blocks * self.block_rows

    @property
    def padded_cols(self) -> int:
        return self.n_col_tiles * self.col_tile


def stack_flat(mats: Sequence[BellMatrix], *, bucket: bool = True) -> StackedFlat:
    """Flatten + pad + stack banked-ELL matrices as packed nonzero streams."""
    if not mats:
        raise ValueError("stack_flat needs at least one matrix")
    r, c = mats[0].block_rows, mats[0].col_tile
    for m in mats:
        if (m.block_rows, m.col_tile) != (r, c):
            raise ValueError("all matrices must share block_rows/col_tile")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    flats = [flatten_bell(m) for m in mats]
    N = rnd(max(f[0].shape[0] for f in flats))
    B = rnd(max(m.n_row_blocks for m in mats))
    n_tiles = rnd(max(m.n_col_tiles for m in mats))
    G = len(mats)
    gcols = np.zeros((G, N), np.int32)
    vals = np.zeros((G, N), mats[0].vals.dtype)
    rows = np.zeros((G, N), np.int32)
    for g, (gc, v, rw) in enumerate(flats):
        gcols[g, : gc.shape[0]] = gc
        vals[g, : v.shape[0]] = v
        rows[g, : rw.shape[0]] = rw
    return StackedFlat(gcols, vals, rows,
                       shapes=tuple(m.shape for m in mats),
                       nnzs=tuple(m.nnz for m in mats),
                       block_rows=r, col_tile=c, n_row_blocks=B,
                       n_col_tiles=n_tiles)


# ---------------------------------------------------------- row-major ELL

#: Above this row-ELL padding ratio (Σ n·W / Σ nnz over the bag, with W
#: the *unbucketed* per-matrix max row width) the automatic layout
#: heuristic (``layout="auto"``) switches from row-ELL to sliced-ELL:
#: below it the global-W padding is cheap enough that the simpler
#: single-rectangle layout wins on dispatch overhead.
SELL_PADDING_THRESHOLD = 2.0

#: SELL-C-σ slice height C (rows per slice) — each C-row slice of the
#: length-sorted rows is padded only to its own max width.
SELL_SLICE_ROWS = 64


def index_dtype(n_pad: int) -> np.dtype:
    """Column-index dtype for a padded row count: ``int16`` when every
    index fits in a signed 16-bit lane (``n_pad < 2^15``), else
    ``int32`` — the narrow-index half of the paper's nonzero stream
    budget (:meth:`repro.core.precision.PrecisionScheme
    .nonzero_stream_bytes`)."""
    return np.dtype(np.int16 if int(n_pad) < (1 << 15) else np.int32)


def index_bytes_for(n: int) -> int:
    """Stream bytes per stored column index for an ``n``-row problem
    once bucketed — what the roofline/byte accounting should charge."""
    return int(index_dtype(bucket_up(n)).itemsize)


def rowell_padding_ratio(csrs: Sequence) -> float:
    """Row-ELL padded-slot overhead ``Σ n·W / Σ nnz`` of a bag (W =
    unbucketed max row width per matrix).  1.0 = no padding; feeds the
    automatic row-ELL vs sliced-ELL choice (:func:`choose_layout`)."""
    tot_nnz = sum(max(int(a.nnz), 1) for a in csrs)
    tot_slots = 0
    for a in csrs:
        rn = np.asarray(a.row_nnz(), np.int64)
        w = max(int(rn.max()) if rn.size else 0, 1)
        tot_slots += a.shape[0] * w
    return tot_slots / max(tot_nnz, 1)


def choose_layout(csrs: Sequence, *, default: str = "rowell",
                  threshold: float = SELL_PADDING_THRESHOLD) -> str:
    """Pick the batched matrix layout for a bag: ``"sell"`` when the
    row-ELL padding ratio exceeds ``threshold`` (skewed row-length
    distributions), else ``default``."""
    return "sell" if rowell_padding_ratio(csrs) > threshold else default


def csr_rowell(a) -> Tuple[np.ndarray, np.ndarray]:
    """Row-major ELL arrays ``(cols int32[n, W], vals[n, W])`` from CSR.

    ``W`` = max nonzeros per row (≥ 1); short rows are padded with
    ``(col i, val 0)`` entries for row ``i`` — the padding *self-gathers*
    the row's own x entry and multiplies it by zero, so a non-finite
    value anywhere else in ``x`` (e.g. a diverging lane elsewhere in the
    batch bucket) can never poison row ``i`` through its padding.
    Entries keep their CSR (sorted-column) order within a row, so the
    SpMV accumulation order is deterministic per row.

    This is the *scatter-free* batched layout: ``y[i] = Σ_w vals[i, w] ·
    x[cols[i, w]]`` is a gather + a dense reduction over the width axis,
    where the packed-stream layout (:func:`flatten_bell` /
    :func:`stack_flat`) needs a segment-sum **scatter** per nonzero —
    ~100 ns/element on XLA CPU, which made the batched solver lose to
    the one-at-a-time python loop by ~30× before the layout switch.
    """
    n = a.shape[0]
    rn = np.asarray(a.row_nnz(), np.int64)
    W = max(int(rn.max()) if n else 0, 1)
    own = np.arange(n, dtype=np.int64)[:, None]
    cols = np.broadcast_to(own, (n, W)).astype(np.int32)
    vals = np.zeros((n, W), a.data.dtype)
    if a.nnz:
        idx = a.indptr[:-1, None] + np.arange(W, dtype=np.int64)[None, :]
        mask = np.arange(W)[None, :] < rn[:, None]
        safe = np.clip(idx, 0, a.nnz - 1)
        cols = np.where(mask, a.indices[safe], own).astype(np.int32)
        vals = np.where(mask, a.data[safe], 0)
    return cols, vals


@dataclasses.dataclass(frozen=True)
class StackedRowEll:
    """B row-major ELL matrices padded to one ``(n_pad, W)`` shape and
    stacked on axis 0 — the batched XLA solver's matrix operand.

    Storage is **slot-major** ``[G, W, n_pad]`` (slot index before row
    index): the SpMV's width reduction is a halving tree over axis 1,
    and slot-major keeps each tree add contiguous over the row lanes.
    Values are packed **at rest** at ``scheme.matrix_dtype`` and column
    indices at :func:`index_dtype` of ``n_pad``, so the stored bytes are
    exactly what the scheme's stream budget charges.  Padded rows
    (beyond a lane's logical ``n``) self-gather their own (zero) x entry
    with val 0; the caller gives them unit diagonal / zero rhs so they
    never influence termination.  Both dims are bucketed (power-of-two
    edges), so the executable cache stays ``O(log n · log nnz_row)``.
    """

    cols: np.ndarray        # int16/int32[G, W, n_pad] column index per slot
    vals: np.ndarray        # matrix_dtype[G, W, n_pad]
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.vals.shape[2])

    @property
    def width(self) -> int:
        return int(self.vals.shape[1])

    @property
    def padding_ratio(self) -> float:
        """Stored slots per logical nonzero (1.0 = no padding)."""
        return self.vals.size / max(sum(self.nnzs), 1)

    @property
    def index_bytes(self) -> int:
        return int(self.cols.dtype.itemsize)

    def stream_bytes_per_nnz(self) -> float:
        """Measured at-rest matrix-stream bytes (values + indices, all
        padding included) per logical nonzero."""
        return (self.vals.nbytes + self.cols.nbytes) / max(sum(self.nnzs), 1)


def stack_rowell(csrs: Sequence, *, bucket: bool = True,
                 scheme=None) -> StackedRowEll:
    """Pad a heterogeneous list of CSR matrices to one row-ELL shape and
    stack along a new leading batch axis (see :func:`csr_rowell`).

    With ``scheme=`` (a :class:`~repro.core.precision.PrecisionScheme`)
    values are cast to ``scheme.matrix_dtype`` here, at stacking time —
    the at-rest packing the paper budgets — instead of per matvec.
    """
    if not csrs:
        raise ValueError("stack_rowell needs at least one matrix")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    lanes = [csr_rowell(a) for a in csrs]
    n_pad = rnd(max(a.shape[0] for a in csrs))
    W = rnd(max(c.shape[1] for c, _ in lanes))
    G = len(csrs)
    vdt = scheme.matrix_dtype if scheme is not None else lanes[0][1].dtype
    idt = index_dtype(n_pad)
    # Every slot self-gathers by default so padded rows/slots read the
    # row's own x entry (see csr_rowell: no cross-row poisoning).
    cols = np.broadcast_to(np.arange(n_pad, dtype=idt),
                           (G, W, n_pad)).copy()
    vals = np.zeros((G, W, n_pad), vdt)
    for g, (c, v) in enumerate(lanes):
        cols[g, : c.shape[1], : c.shape[0]] = c.T
        vals[g, : v.shape[1], : v.shape[0]] = v.T.astype(vdt)
    return StackedRowEll(cols, vals,
                         shapes=tuple(a.shape for a in csrs),
                         nnzs=tuple(a.nnz for a in csrs))


# ------------------------------------------------------- sliced ELL (SELL)
@dataclasses.dataclass(frozen=True)
class StackedSell:
    """B matrices in a stacked **SELL-C-σ** (sliced-ELL) layout.

    Rows are sorted by descending nonzero count within σ-row windows
    (stable, so equal-length rows keep their order), sliced into C-row
    chunks, and each slice is padded only to its own (cross-lane,
    bucketed) max width — skewed matrices store ~nnz slots instead of
    row-ELL's ``n·W``.  Contiguous equal-width slices are merged into
    static ``(rows, width)`` *groups*; group data is stored slot-major
    (``[width, rows]`` flattened) back to back in flat ``[G, L]``
    arrays, values at ``scheme.matrix_dtype`` and indices at
    :func:`index_dtype` — the at-rest packing the stream budget charges.

    ``iperm[g, i]`` is the sorted position of original row ``i``:
    ``y = take_along_axis(y_sorted, iperm, axis=1)`` undoes the sort.
    Within-row slot order is untouched by the permutation and the
    per-row reduction uses the same halving tree as row-ELL, so SpMV
    results are **bit-identical** to row-ELL for every scheme.  Padded
    slots self-gather (col = own row id, val 0) like row-ELL.
    """

    cols: np.ndarray    # int16/int32[G, L] flat slot-major column ids
    vals: np.ndarray    # matrix_dtype[G, L]
    iperm: np.ndarray   # int32[G, n_pad] original row -> sorted position
    groups: Tuple[Tuple[int, int], ...]  # static (rows, width) runs
    slice_rows: int     # C
    sort_window: int    # σ
    shapes: Tuple[Tuple[int, int], ...]
    nnzs: Tuple[int, ...]

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return int(self.iperm.shape[1])

    @property
    def total_slots(self) -> int:
        return int(self.vals.shape[1])

    @property
    def padding_ratio(self) -> float:
        """Stored slots per logical nonzero (1.0 = no padding)."""
        return self.vals.size / max(sum(self.nnzs), 1)

    @property
    def index_bytes(self) -> int:
        return int(self.cols.dtype.itemsize)

    def stream_bytes_per_nnz(self) -> float:
        """Measured at-rest matrix-stream bytes (values + indices, all
        padding included) per logical nonzero."""
        return (self.vals.nbytes + self.cols.nbytes) / max(sum(self.nnzs), 1)


def sell_slice_widths(csrs: Sequence, *, n_pad: int,
                      slice_rows: int = SELL_SLICE_ROWS,
                      sort_window: int | None = None,
                      bucket: bool = True) -> Tuple[int, ...]:
    """Per-slice padded widths a :func:`stack_sell` of this bag would
    use at the given ``n_pad`` — the growable half of a serving pool's
    sell bucket signature (widths only ever grow as lanes are merged)."""
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    C = max(1, min(int(slice_rows), n_pad))
    sigma = n_pad if sort_window is None else max(C, min(int(sort_window),
                                                         n_pad))
    widths = None
    for a in csrs:
        rn = np.zeros(n_pad, np.int64)
        rn[: a.shape[0]] = a.row_nnz()
        srt = np.concatenate([np.sort(rn[w0:min(w0 + sigma, n_pad)])[::-1]
                              for w0 in range(0, n_pad, sigma)])
        lane = [int(srt[r0:min(r0 + C, n_pad)].max())
                for r0 in range(0, n_pad, C)]
        widths = lane if widths is None else [max(x, y) for x, y
                                              in zip(widths, lane)]
    return tuple(int(rnd(w)) if w > 0 else 0 for w in widths)


def _sell_groups(widths: Sequence[int], *, n_pad: int,
                 slice_rows: int) -> Tuple[Tuple[int, int], ...]:
    """Merge contiguous equal-width slices into static (rows, width)
    groups; Σ rows = n_pad."""
    groups: list = []
    for s, w in enumerate(widths):
        rows = min(slice_rows, n_pad - s * slice_rows)
        if groups and groups[-1][1] == w:
            groups[-1] = (groups[-1][0] + rows, w)
        else:
            groups.append((rows, w))
    return tuple((int(r), int(w)) for r, w in groups)


def stack_sell(csrs: Sequence, *, bucket: bool = True, scheme=None,
               slice_rows: int = SELL_SLICE_ROWS,
               sort_window: int | None = None,
               n_pad: int | None = None,
               widths: Sequence[int] | None = None) -> StackedSell:
    """Stack a heterogeneous list of CSR matrices in SELL-C-σ layout
    (see :class:`StackedSell`).  ``sort_window=None`` sorts globally
    (σ = n_pad, maximum padding compression); widths are shared across
    lanes and bucketed to power-of-two edges when ``bucket=True``.

    ``n_pad``/``widths`` override the derived geometry — the serving
    pool uses them to pack a single admitted lane into an existing
    pool bucket without re-deriving (and possibly shrinking) the
    shared slice widths.  Given widths must cover the data
    (``ValueError`` otherwise: a too-narrow slice would silently drop
    nonzeros)."""
    if not csrs:
        raise ValueError("stack_sell needs at least one matrix")
    rnd = bucket_up if bucket else (lambda x, minimum=1: max(int(x), minimum))
    G = len(csrs)
    n_auto = rnd(max(a.shape[0] for a in csrs))
    n_pad = n_auto if n_pad is None else int(n_pad)
    if n_pad < max(a.shape[0] for a in csrs):
        raise ValueError(f"n_pad={n_pad} smaller than the largest lane")
    C = max(1, min(int(slice_rows), n_pad))
    sigma = n_pad if sort_window is None else max(C, min(int(sort_window),
                                                         n_pad))
    vdt = np.dtype(scheme.matrix_dtype) if scheme is not None \
        else np.asarray(csrs[0].data).dtype
    idt = index_dtype(n_pad)

    # Per-lane padded row-nnz + stable descending-length sort within
    # σ-row windows.
    rns, perms = [], []
    iperm = np.zeros((G, n_pad), np.int32)
    for g, a in enumerate(csrs):
        rn = np.zeros(n_pad, np.int64)
        rn[: a.shape[0]] = a.row_nnz()
        perm = np.empty(n_pad, np.int64)
        for w0 in range(0, n_pad, sigma):
            w1 = min(w0 + sigma, n_pad)
            perm[w0:w1] = w0 + np.argsort(-rn[w0:w1], kind="stable")
        inv = np.empty(n_pad, np.int64)
        inv[perm] = np.arange(n_pad)
        rns.append(rn)
        perms.append(perm)
        iperm[g] = inv.astype(np.int32)

    # Shared per-slice widths: cross-lane max, bucketed; 0 = all-empty.
    n_slices = -(-n_pad // C)
    need = []
    for s in range(n_slices):
        r0, r1 = s * C, min((s + 1) * C, n_pad)
        need.append(max(int(rns[g][perms[g][r0:r1]].max())
                        for g in range(G)))
    if widths is None:
        widths = [int(rnd(w)) if w > 0 else 0 for w in need]
    else:
        widths = [int(w) for w in widths]
        if len(widths) != n_slices or any(w < d for w, d in
                                          zip(widths, need)):
            raise ValueError(
                f"given widths {widths} do not cover the data's "
                f"per-slice requirements {need} at n_pad={n_pad}")
    groups = _sell_groups(widths, n_pad=n_pad, slice_rows=C)
    L = sum(r * w for r, w in groups)

    cols = np.zeros((G, max(L, 1)), idt)[:, :L]
    vals = np.zeros((G, max(L, 1)), vdt)[:, :L]
    for g, a in enumerate(csrs):
        n = a.shape[0]
        rn, perm = rns[g], perms[g]
        ip = np.full(n_pad, a.nnz, np.int64)
        ip[:n] = a.indptr[:-1]
        off = r0 = 0
        for rows, w in groups:
            rws = perm[r0:r0 + rows]
            r0 += rows
            if w == 0:
                continue
            if a.nnz:
                idx = ip[rws][:, None] + np.arange(w, dtype=np.int64)[None, :]
                mask = np.arange(w)[None, :] < rn[rws][:, None]
                safe = np.clip(idx, 0, a.nnz - 1)
                c = np.where(mask, a.indices[safe], rws[:, None])
                v = np.where(mask, a.data[safe], 0)
            else:
                c = np.broadcast_to(rws[:, None], (rows, w))
                v = np.zeros((rows, w), a.data.dtype)
            cols[g, off:off + rows * w] = c.T.astype(idt).ravel()
            vals[g, off:off + rows * w] = v.T.astype(vdt).ravel()
            off += rows * w
    return StackedSell(cols, vals, iperm, groups, slice_rows=C,
                       sort_window=sigma,
                       shapes=tuple(a.shape for a in csrs),
                       nnzs=tuple(a.nnz for a in csrs))
