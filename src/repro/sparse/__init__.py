"""Sparse-matrix substrate for the Callipepla-JAX solver stack.

Formats:
  * :mod:`repro.sparse.csr`      — host-side CSR container + reference ops.
  * :mod:`repro.sparse.bell`     — banked-ELL ("streams") format: the TPU
    adaptation of Serpens'/Callipepla's per-channel packed nonzero streams.
  * :mod:`repro.sparse.mtx`      — MatrixMarket I/O (SuiteSparse-compatible).
  * :mod:`repro.sparse.generators` — synthetic SPD problem generators that
    cover the regimes of the paper's Table 3 benchmark suite.
  * :mod:`repro.sparse.partition` — row-block partitioning for multi-chip CG.
  * :mod:`repro.sparse.stacking` — bucketed padding/stacking for the batched
    multi-system solver (:mod:`repro.core.batch`).
"""
from repro.sparse.csr import CSRMatrix, csr_from_coo, csr_to_dense, csr_spmv
from repro.sparse.bell import BellMatrix, csr_to_bell, bell_spmv_reference
from repro.sparse.generators import (
    poisson_2d,
    poisson_3d,
    random_spd,
    diag_dominant_spd,
    powerlaw_spd,
    tridiagonal_spd,
    benchmark_suite,
)
from repro.sparse.mtx import read_mtx, write_mtx
from repro.sparse.partition import partition_rows, PartitionedMatrix
from repro.sparse.stacking import (bucket_up, pad_bell, pad_ellpack,
                                   stack_bell, stack_ellpack, stack_rowell,
                                   stack_sell, StackedBell, StackedEllpack,
                                   StackedRowEll, StackedSell, index_dtype,
                                   index_bytes_for, rowell_padding_ratio,
                                   choose_layout)

__all__ = [
    "CSRMatrix", "csr_from_coo", "csr_to_dense", "csr_spmv",
    "BellMatrix", "csr_to_bell", "bell_spmv_reference",
    "poisson_2d", "poisson_3d", "random_spd", "diag_dominant_spd",
    "powerlaw_spd", "tridiagonal_spd", "benchmark_suite",
    "read_mtx", "write_mtx",
    "partition_rows", "PartitionedMatrix",
    "bucket_up", "pad_bell", "pad_ellpack", "stack_bell", "stack_ellpack",
    "stack_rowell", "stack_sell", "StackedBell", "StackedEllpack",
    "StackedRowEll", "StackedSell", "index_dtype", "index_bytes_for",
    "rowell_padding_ratio", "choose_layout",
]
