"""Banked ELLPACK — the Pallas-kernel-facing matrix layout.

The flat-slab banked-ELL (:mod:`repro.sparse.bell`) keeps explicit
``local_rows`` and needs a scatter-add per slab — natural for the FPGA's
8 write-ported URAM Y-memory, hostile to a SIMD TPU core (VMEM scatter is
serialized).  The TPU-native statement of the same idea assigns **one
vector lane per row**, which makes the row index *implicit* and turns the
Y-memory update into a plain vectorized add:

* rows are grouped into **row blocks** of ``block_rows`` (lane-aligned,
  multiple of 128);
* the columns a row block touches are grouped into **col tiles** of
  ``col_tile`` (the VMEM-resident x-tile, BRAM X-memory analogue);
* within a (row-block, col-tile) cell every row stores its nonzeros in
  ``ell`` *slots*; arrays are slot-major ``[B, T, ell, block_rows]`` so a
  slot is one full vector op across 256 lanes — the TPU spelling of
  "8 PEs consume 8 nonzeros per cycle at II=1";
* ``tile_cols[B, T]`` lists which x-tile each slab wants.  It is the
  kernel's Type-III memory-instruction stream: scalar-prefetched, it
  drives the x BlockSpec ``index_map`` (prefetching, paper §4.2).

Padding entries carry ``val = 0, local_col = 0`` and contribute
``0 * x[tile_base]``.  ``padding_efficiency`` reports the waste; for
stencil/FEM matrices (the paper's Table 3 classes) it stays near 1.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix

__all__ = ["EllpackMatrix", "csr_to_ellpack", "ellpack_spmv_reference"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class EllpackMatrix:
    """Slot-major banked ELLPACK (host numpy; device placement at use site)."""

    tile_cols: np.ndarray   # int32[B, T]        x-tile id per slab
    vals: np.ndarray        # v[B, T, ell, R]    slot-major values
    local_cols: np.ndarray  # int32[B, T, ell, R] in [0, col_tile)
    shape: Tuple[int, int]  # logical (unpadded) shape
    block_rows: int
    col_tile: int
    nnz: int

    @property
    def n_row_blocks(self) -> int:
        return int(self.vals.shape[0])

    @property
    def n_slabs(self) -> int:
        return int(self.vals.shape[1])

    @property
    def ell(self) -> int:
        return int(self.vals.shape[2])

    @property
    def padded_rows(self) -> int:
        return self.n_row_blocks * self.block_rows

    @property
    def padded_cols(self) -> int:
        return _round_up(self.shape[1], self.col_tile)

    @property
    def n_col_tiles(self) -> int:
        return self.padded_cols // self.col_tile

    @property
    def stored_entries(self) -> int:
        return int(np.prod(self.vals.shape))

    @property
    def padding_efficiency(self) -> float:
        return self.nnz / max(1, self.stored_entries)

    def astype(self, dtype) -> "EllpackMatrix":
        return dataclasses.replace(self, vals=self.vals.astype(dtype))

    def stream_bytes(self, value_bytes: int | None = None,
                     index_bytes: int = 2) -> int:
        """HBM bytes one SpMV streams for the matrix operand (value +
        one local col index per stored entry; rows are implicit — half
        the index traffic of the flat-slab layout, the Serpens 14-bit
        packing taken one step further)."""
        if value_bytes is None:
            value_bytes = self.vals.dtype.itemsize
        return self.stored_entries * (value_bytes + index_bytes)


def csr_to_ellpack(a: CSRMatrix, *, block_rows: int = 256,
                   col_tile: int = 512) -> EllpackMatrix:
    """Convert CSR to slot-major banked ELLPACK.

    ``block_rows`` should be a multiple of 128 (TPU lanes) and
    ``col_tile`` a multiple of 128 for the real kernel; relaxed values are
    allowed for tests/interpret mode.
    """
    n_rows, n_cols = a.shape
    B = max(1, -(-n_rows // block_rows))

    row_ids = np.repeat(np.arange(n_rows, dtype=np.int64), a.row_nnz())
    col_ids = a.indices.astype(np.int64)
    blk = row_ids // block_rows
    tile = col_ids // col_tile

    if row_ids.size == 0:
        z = np.zeros((B, 1, 1, block_rows), dtype=a.data.dtype)
        zi = np.zeros((B, 1, 1, block_rows), dtype=np.int32)
        return EllpackMatrix(np.zeros((B, 1), np.int32), z, zi, a.shape,
                             block_rows, col_tile, 0)

    # CSR order is already (row, col) sorted -> (blk, tile) groups are
    # contiguous per row; sort globally by (blk, tile, row).
    order = np.lexsort((row_ids, tile, blk))
    blk_s, tile_s, row_s = blk[order], tile[order], row_ids[order]
    lcol_s = (col_ids[order] - tile_s * col_tile).astype(np.int32)
    vals_s = a.data[order]
    lrow_s = (row_s - blk_s * block_rows).astype(np.int32)

    # Slab id: rank of this (blk, tile) cell among the block's cells.
    cell_change = np.empty(blk_s.shape[0], dtype=bool)
    cell_change[0] = True
    cell_change[1:] = (blk_s[1:] != blk_s[:-1]) | (tile_s[1:] != tile_s[:-1])
    cell_id = np.cumsum(cell_change) - 1
    cell_blk = blk_s[cell_change]
    cell_tile = tile_s[cell_change]
    blk_change = np.empty(cell_blk.shape[0], dtype=bool)
    blk_change[0] = True
    blk_change[1:] = cell_blk[1:] != cell_blk[:-1]
    first_cell_of_blk = np.maximum.accumulate(
        np.where(blk_change, np.arange(cell_blk.size), 0))
    cell_slot = np.arange(cell_blk.size) - first_cell_of_blk
    T = int(cell_slot.max()) + 1

    # Slot of each nonzero within its (cell, row): rank among same-row
    # entries of the cell.  Entries are sorted by (cell, row), so:
    rowkey_change = cell_change | np.concatenate(
        [[True], row_s[1:] != row_s[:-1]])
    idx = np.arange(blk_s.shape[0])
    run_start = np.maximum.accumulate(np.where(rowkey_change, idx, 0))
    slot = idx - run_start
    ell = int(slot.max()) + 1

    tile_cols = np.zeros((B, T), dtype=np.int32)
    tile_cols[cell_blk, cell_slot] = cell_tile.astype(np.int32)
    vals = np.zeros((B, T, ell, block_rows), dtype=a.data.dtype)
    lcols = np.zeros((B, T, ell, block_rows), dtype=np.int32)
    s_of_nz = cell_slot[cell_id]
    vals[blk_s, s_of_nz, slot, lrow_s] = vals_s
    lcols[blk_s, s_of_nz, slot, lrow_s] = lcol_s

    return EllpackMatrix(tile_cols, vals, lcols, a.shape, block_rows,
                         col_tile, a.nnz)


def ellpack_spmv_reference(m: EllpackMatrix, x: np.ndarray,
                           out_dtype=np.float64) -> np.ndarray:
    """Golden numpy SpMV over the ELLPACK layout (kernel dataflow order)."""
    x_pad = np.zeros(m.padded_cols, dtype=out_dtype)
    x_pad[: x.shape[0]] = x.astype(out_dtype)
    y = np.zeros(m.padded_rows, dtype=out_dtype)
    R, C = m.block_rows, m.col_tile
    for i in range(m.n_row_blocks):
        acc = np.zeros(R, dtype=out_dtype)
        for t in range(m.n_slabs):
            xt = x_pad[int(m.tile_cols[i, t]) * C:][:C]
            for e in range(m.ell):
                acc += m.vals[i, t, e].astype(out_dtype) * xt[m.local_cols[i, t, e]]
        y[i * R:(i + 1) * R] = acc
    return y[: m.shape[0]]
