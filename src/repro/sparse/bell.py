"""Banked-ELL ("streams") sparse format — the TPU adaptation of Serpens.

Callipepla/Serpens feed each HBM pseudo-channel a stream of 64-bit packed
nonzeros ``(14-bit col, 18-bit row, fp32 val)`` consumed by 8 PEs at II=1.
On TPU there are no per-channel FIFOs, so the same idea — *pre-scheduled,
padded, bank-conflict-free nonzero streams with locally-addressable indices*
— becomes a 2-level blocked layout consumed by a Pallas kernel:

* rows are grouped into **row blocks** of ``block_rows`` (the Y-memory /
  URAM analogue: one output tile held in VMEM per grid step);
* columns are grouped into **col tiles** of ``col_tile`` (the X-memory /
  BRAM analogue: one input-vector tile resident in VMEM while a slab
  streams past it);
* the nonzeros of each (row-block, col-tile) cell form a **slab**, padded
  to a fixed ``slab_len``; indices are stored *relative to the block/tile
  base* so they fit small integers — the TPU analogue of Serpens' 14-bit
  column packing (index bandwidth is halved vs. global int32 pairs);
* each row block stores the *list of col tiles it touches*
  (``tile_cols``).  This array is the kernel's **memory-instruction
  stream**: it is scalar-prefetched and drives the BlockSpec ``index_map``,
  exactly the role Type-III memory instructions play in the paper.

Dummy (padding) entries have ``val = 0`` and local indices ``0`` so they
contribute ``0 * x[tile_base]`` to row ``block_base`` — harmless.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix

__all__ = ["BellMatrix", "csr_to_bell", "bell_spmv_reference"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BellMatrix:
    """Banked-ELL matrix (host numpy arrays; device placement at use site)."""

    tile_cols: np.ndarray   # int32[n_row_blocks, n_slabs]  col-tile id per slab
    vals: np.ndarray        # v[n_row_blocks, n_slabs, slab_len]
    local_rows: np.ndarray  # int32[same] in [0, block_rows)
    local_cols: np.ndarray  # int32[same] in [0, col_tile)
    shape: Tuple[int, int]  # logical (unpadded) shape
    block_rows: int
    col_tile: int
    nnz: int                # true nonzeros (excludes padding)

    @property
    def n_row_blocks(self) -> int:
        return int(self.vals.shape[0])

    @property
    def n_slabs(self) -> int:
        return int(self.vals.shape[1])

    @property
    def slab_len(self) -> int:
        return int(self.vals.shape[2])

    @property
    def padded_rows(self) -> int:
        return self.n_row_blocks * self.block_rows

    @property
    def padded_cols(self) -> int:
        return _round_up(self.shape[1], self.col_tile)

    @property
    def n_col_tiles(self) -> int:
        return self.padded_cols // self.col_tile

    @property
    def stored_entries(self) -> int:
        return int(np.prod(self.vals.shape))

    @property
    def padding_efficiency(self) -> float:
        """nnz / stored entries — 1.0 means zero padding waste."""
        return self.nnz / max(1, self.stored_entries)

    def astype(self, dtype) -> "BellMatrix":
        return dataclasses.replace(self, vals=self.vals.astype(dtype))

    def stream_bytes(self, value_bytes: int | None = None, index_bytes: int = 2) -> int:
        """HBM bytes one SpMV streams for the matrix operand.

        Serpens packs (col, row, val) in 8 bytes; our slab entry is
        ``value_bytes + 2 * index_bytes`` (local indices fit int16 whenever
        block_rows, col_tile <= 32768, which is always true here).
        """
        if value_bytes is None:
            value_bytes = self.vals.dtype.itemsize
        return self.stored_entries * (value_bytes + 2 * index_bytes)


def csr_to_bell(a: CSRMatrix, *, block_rows: int = 256, col_tile: int = 512,
                pad_slab_to: int = 8) -> BellMatrix:
    """Convert CSR to banked-ELL.

    ``block_rows`` multiple of 8 (TPU sublane), ``col_tile`` multiple of 128
    (TPU lane) for the real kernel; relaxed values are allowed for tests.
    """
    n_rows, n_cols = a.shape
    n_row_blocks = max(1, -(-n_rows // block_rows))

    row_ids = np.repeat(np.arange(n_rows, dtype=np.int64), a.row_nnz())
    col_ids = a.indices.astype(np.int64)
    blk = row_ids // block_rows
    tile = col_ids // col_tile

    # Sort nonzeros by (row block, col tile); stable keeps row-major order
    # inside a slab, which mirrors the paper's in-stream ordering.
    order = np.lexsort((row_ids, tile, blk))
    blk_s, tile_s = blk[order], tile[order]
    lrow_s = (row_ids[order] - blk_s * block_rows).astype(np.int32)
    lcol_s = (col_ids[order] - tile_s * col_tile).astype(np.int32)
    vals_s = a.data[order]

    if blk_s.size == 0:
        n_slabs, slab_len = 1, pad_slab_to
        tile_cols = np.zeros((n_row_blocks, n_slabs), dtype=np.int32)
        z = np.zeros((n_row_blocks, n_slabs, slab_len), dtype=a.data.dtype)
        zi = np.zeros((n_row_blocks, n_slabs, slab_len), dtype=np.int32)
        return BellMatrix(tile_cols, z, zi, zi.copy(), a.shape, block_rows, col_tile, 0)

    # Group boundaries over (blk, tile) pairs.
    key_change = np.empty(blk_s.shape[0], dtype=bool)
    key_change[0] = True
    key_change[1:] = (blk_s[1:] != blk_s[:-1]) | (tile_s[1:] != tile_s[:-1])
    group = np.cumsum(key_change) - 1                     # group id per nnz
    g_start = np.flatnonzero(key_change)
    g_count = np.diff(np.append(g_start, blk_s.shape[0]))
    g_blk = blk_s[g_start]
    g_tile = tile_s[g_start]

    # Slab slot of each group within its row block (rank of tile in block).
    blk_change = np.empty(g_blk.shape[0], dtype=bool)
    blk_change[0] = True
    blk_change[1:] = g_blk[1:] != g_blk[:-1]
    first_group_of_blk = np.maximum.accumulate(np.where(blk_change, np.arange(g_blk.size), 0))
    g_slot = np.arange(g_blk.size) - first_group_of_blk

    n_slabs = int(g_slot.max()) + 1
    slab_len = _round_up(int(g_count.max()), pad_slab_to)

    tile_cols = np.zeros((n_row_blocks, n_slabs), dtype=np.int32)
    vals = np.zeros((n_row_blocks, n_slabs, slab_len), dtype=a.data.dtype)
    local_rows = np.zeros((n_row_blocks, n_slabs, slab_len), dtype=np.int32)
    local_cols = np.zeros((n_row_blocks, n_slabs, slab_len), dtype=np.int32)

    tile_cols[g_blk, g_slot] = g_tile.astype(np.int32)
    # Position of each nonzero within its slab.
    pos_in_group = np.arange(blk_s.shape[0]) - g_start[group]
    vals[blk_s, g_slot[group], pos_in_group] = vals_s
    local_rows[blk_s, g_slot[group], pos_in_group] = lrow_s
    local_cols[blk_s, g_slot[group], pos_in_group] = lcol_s

    return BellMatrix(tile_cols, vals, local_rows, local_cols,
                      a.shape, block_rows, col_tile, a.nnz)


def bell_spmv_reference(m: BellMatrix, x: np.ndarray,
                        out_dtype=np.float64) -> np.ndarray:
    """Golden numpy SpMV over the banked-ELL layout (slab accumulation order).

    Matches the kernel's dataflow: for each (row block, slab): gather the
    x col-tile, multiply by slab values, scatter-add to the y row block.
    """
    x_pad = np.zeros(m.padded_cols, dtype=out_dtype)
    x_pad[: x.shape[0]] = x.astype(out_dtype)
    y = np.zeros(m.padded_rows, dtype=out_dtype)
    C, R = m.col_tile, m.block_rows
    for i in range(m.n_row_blocks):
        for t in range(m.n_slabs):
            base = int(m.tile_cols[i, t]) * C
            xt = x_pad[base: base + C]
            prod = m.vals[i, t].astype(out_dtype) * xt[m.local_cols[i, t]]
            np.add.at(y, i * R + m.local_rows[i, t], prod)
    return y[: m.shape[0]]
