"""Row-block partitioning of sparse matrices for multi-chip CG.

The distributed SpMV is 1-D row-partitioned (the standard decomposition for
CG: every vector op is then purely local except the dot products, which are
single-scalar ``psum``s).  Each shard receives an equal-shaped banked-ELL
slice so the stacked arrays can be consumed by ``shard_map`` directly.

Column handling: shards reference *global* column tiles; the kernel gathers
from an all-gathered (or halo-exchanged) x.  For stencil-class matrices the
column span of a shard is a narrow window — ``halo_width`` reports it so the
distributed layer can choose halo exchange (collective_permute with
neighbors) over all-gather.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.sparse.bell import BellMatrix, csr_to_bell
from repro.sparse.csr import CSRMatrix

__all__ = ["partition_rows", "PartitionedMatrix"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class PartitionedMatrix:
    """Equal-shaped BELL shards stacked on a leading shard axis."""

    tile_cols: np.ndarray    # int32[S, B, T]
    vals: np.ndarray         # v[S, B, T, L]
    local_rows: np.ndarray   # int32[S, B, T, L]
    local_cols: np.ndarray   # int32[S, B, T, L]
    shape: Tuple[int, int]   # global logical shape
    rows_per_shard: int      # padded rows each shard owns
    block_rows: int
    col_tile: int
    nnz: int
    halo_width: int          # max |col - row-window| over shards (for stencils)

    # ---- neighbor-halo exchange (stencil fast path) --------------------
    @property
    def halo_pad(self) -> int:
        """Halo rounded up to a whole number of col tiles."""
        return -(-self.halo_width // self.col_tile) * self.col_tile

    @property
    def supports_halo(self) -> bool:
        """One-hop halo: window fits in the two adjacent shards and tile
        alignment holds (col_tile | rows_per_shard)."""
        return (self.halo_width > 0
                and self.halo_pad <= self.rows_per_shard
                and self.rows_per_shard % self.col_tile == 0)

    def tile_cols_halo(self) -> np.ndarray:
        """Per-shard tile ids remapped into the local halo window
        ``[k·R − halo_pad, (k+1)·R + halo_pad)`` — the collective drops
        from an all-gather of x to two neighbor permutes.  Padding slabs
        (zero values) clamp into range; their 0-valued entries contribute
        nothing wherever they read."""
        S = self.n_shards
        C = self.col_tile
        w_tiles = (self.rows_per_shard + 2 * self.halo_pad) // C
        out = np.zeros_like(self.tile_cols)
        for k in range(S):
            ws = (k * self.rows_per_shard - self.halo_pad) // C
            out[k] = np.clip(self.tile_cols[k] - ws, 0, w_tiles - 1)
        return out

    @property
    def n_shards(self) -> int:
        return int(self.vals.shape[0])

    @property
    def padded_rows(self) -> int:
        return self.n_shards * self.rows_per_shard

    @property
    def padded_cols(self) -> int:
        return _round_up(self.shape[1], self.col_tile)

    def shard(self, k: int) -> BellMatrix:
        return BellMatrix(self.tile_cols[k], self.vals[k], self.local_rows[k],
                          self.local_cols[k],
                          (self.rows_per_shard, self.shape[1]),
                          self.block_rows, self.col_tile, -1)


def partition_rows(a: CSRMatrix, n_shards: int, *, block_rows: int = 256,
                   col_tile: int = 512, pad_slab_to: int = 8) -> PartitionedMatrix:
    """Split ``a`` into ``n_shards`` equal row slices, BELL-encode each,
    and pad all shards to a common (n_slabs, slab_len)."""
    n_rows, n_cols = a.shape
    rows_per_shard = _round_up(-(-n_rows // n_shards), block_rows)

    shards: List[BellMatrix] = []
    halo = 0
    for k in range(n_shards):
        r0 = k * rows_per_shard
        r1 = min(n_rows, (k + 1) * rows_per_shard)
        if r0 >= n_rows:
            # Empty shard (padding at the tail of the shard axis).
            indptr = np.zeros(rows_per_shard + 1, dtype=np.int64)
            sl = CSRMatrix(indptr, np.zeros(0, np.int32),
                           np.zeros(0, a.data.dtype), (rows_per_shard, n_cols))
        else:
            lo, hi = a.indptr[r0], a.indptr[r1]
            indptr = np.zeros(rows_per_shard + 1, dtype=np.int64)
            indptr[: r1 - r0 + 1] = a.indptr[r0: r1 + 1] - lo
            indptr[r1 - r0 + 1:] = indptr[r1 - r0]
            sl = CSRMatrix(indptr, a.indices[lo:hi], a.data[lo:hi],
                           (rows_per_shard, n_cols))
            if hi > lo:
                cols = a.indices[lo:hi].astype(np.int64)
                halo = max(halo, int(max(r0 - cols.min(), cols.max() - (r1 - 1), 0)))
        shards.append(csr_to_bell(sl, block_rows=block_rows, col_tile=col_tile,
                                  pad_slab_to=pad_slab_to))

    n_slabs = max(s.n_slabs for s in shards)
    slab_len = max(s.slab_len for s in shards)
    B = rows_per_shard // block_rows

    def pad(arr: np.ndarray, dt) -> np.ndarray:
        out = np.zeros((B, n_slabs, slab_len), dtype=dt)
        out[:, : arr.shape[1], : arr.shape[2]] = arr
        return out

    tile_cols = np.zeros((n_shards, B, n_slabs), dtype=np.int32)
    vals = np.zeros((n_shards, B, n_slabs, slab_len), dtype=a.data.dtype)
    lrows = np.zeros((n_shards, B, n_slabs, slab_len), dtype=np.int32)
    lcols = np.zeros((n_shards, B, n_slabs, slab_len), dtype=np.int32)
    for k, s in enumerate(shards):
        tile_cols[k, :, : s.n_slabs] = s.tile_cols
        vals[k] = pad(s.vals, a.data.dtype)
        lrows[k] = pad(s.local_rows, np.int32)
        lcols[k] = pad(s.local_cols, np.int32)

    return PartitionedMatrix(tile_cols, vals, lrows, lcols, a.shape,
                             rows_per_shard, block_rows, col_tile, a.nnz, halo)
