"""Synthetic SPD problem generators.

SuiteSparse is not reachable offline, so these generators reproduce the
*regimes* of the paper's Table 3 benchmark suite:

* ``poisson_2d`` / ``poisson_3d`` — discretized Laplacians: the
  `ecology2` / `tmt_sym` / `thermal` class (large N, ~5–7 nnz/row, κ ~ N).
* ``diag_dominant_spd`` — random structural-like matrices with tunable
  nnz/row and diagonal dominance: the `bcsstk` / `msc` / `raefsky` class
  (dominance → 1⁺ gives the slow-converging, 10k+-iteration problems that
  separate Mix-V1/V2 from Mix-V3 in the paper's Fig. 9).
* ``tridiagonal_spd`` — 1-D Poisson, exact spectrum known (κ controllable),
  used by property tests.
* ``benchmark_suite`` — named problem set with small/medium/large tiers
  mirroring Table 3's M1–M18 (3.9k–23k rows) and M19–M36 (123k–1.56M rows).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo

__all__ = [
    "poisson_2d", "poisson_3d", "tridiagonal_spd", "random_spd",
    "diag_dominant_spd", "powerlaw_spd", "benchmark_suite",
]


def poisson_2d(nx: int, ny: int | None = None, dtype=np.float64) -> CSRMatrix:
    """5-point Laplacian on an nx×ny grid (SPD, κ = O(n²))."""
    ny = ny or nx
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    rows, cols, vals = [idx.ravel()], [idx.ravel()], [np.full(n, 4.0)]
    for shift, axis in (((-1, 0), 0), ((1, 0), 0), ((0, -1), 1), ((0, 1), 1)):
        src = idx
        if axis == 0:
            dst = np.roll(idx, shift[0], axis=0)
            valid = np.ones_like(idx, dtype=bool)
            if shift[0] == -1:
                valid[-1, :] = False
            else:
                valid[0, :] = False
        else:
            dst = np.roll(idx, shift[1], axis=1)
            valid = np.ones_like(idx, dtype=bool)
            if shift[1] == -1:
                valid[:, -1] = False
            else:
                valid[:, 0] = False
        rows.append(src[valid].ravel())
        cols.append(dst[valid].ravel())
        vals.append(np.full(valid.sum(), -1.0))
    return csr_from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals).astype(dtype), (n, n))


def poisson_3d(n_side: int, dtype=np.float64) -> CSRMatrix:
    """7-point Laplacian on an n³ grid."""
    n = n_side ** 3
    idx = np.arange(n).reshape(n_side, n_side, n_side)
    rows, cols, vals = [idx.ravel()], [idx.ravel()], [np.full(n, 6.0)]
    for axis in range(3):
        for d in (-1, 1):
            dst = np.roll(idx, d, axis=axis)
            valid = np.ones_like(idx, dtype=bool)
            sl = [slice(None)] * 3
            sl[axis] = -1 if d == -1 else 0
            valid[tuple(sl)] = False
            rows.append(idx[valid].ravel())
            cols.append(dst[valid].ravel())
            vals.append(np.full(valid.sum(), -1.0))
    return csr_from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals).astype(dtype), (n, n))


def tridiagonal_spd(n: int, off: float = -1.0, diag: float = 2.0,
                    dtype=np.float64) -> CSRMatrix:
    """1-D Poisson [off, diag, off]; SPD iff diag > 2|off|·cos(π/(n+1))."""
    i = np.arange(n)
    rows = np.concatenate([i, i[:-1], i[1:]])
    cols = np.concatenate([i, i[1:], i[:-1]])
    vals = np.concatenate([np.full(n, diag), np.full(n - 1, off), np.full(n - 1, off)])
    return csr_from_coo(rows, cols, vals.astype(dtype), (n, n))


def diag_dominant_spd(n: int, nnz_per_row: int = 16, dominance: float = 1.05,
                      seed: int = 0, dtype=np.float64) -> CSRMatrix:
    """Random symmetric matrix with |a_ii| = dominance · Σ|a_ij|.

    ``dominance`` → 1⁺ yields ill-conditioned SPD systems (thousands of CG
    iterations, where mixed-precision schemes diverge in behavior);
    dominance ≫ 1 yields easy, well-conditioned systems.
    """
    rng = np.random.default_rng(seed)
    half = max(1, nnz_per_row // 2)
    rows = np.repeat(np.arange(n), half)
    cols = rng.integers(0, n, size=rows.shape[0])
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    vals = rng.standard_normal(rows.shape[0])
    # Symmetrize: add the transpose triplets.
    rows_s = np.concatenate([rows, cols])
    cols_s = np.concatenate([cols, rows])
    vals_s = np.concatenate([vals, vals])
    a = csr_from_coo(rows_s, cols_s, vals_s.astype(dtype), (n, n))
    # Enforce diagonal dominance: diag = dominance * row abs-sum.
    row_ids = np.repeat(np.arange(n), a.row_nnz())
    abssum = np.bincount(row_ids, weights=np.abs(a.data), minlength=n)
    diag_rows = np.arange(n)
    diag_vals = dominance * np.maximum(abssum, 1e-8)
    all_rows = np.concatenate([row_ids, diag_rows])
    all_cols = np.concatenate([a.indices.astype(np.int64), diag_rows])
    all_vals = np.concatenate([a.data, diag_vals.astype(dtype)])
    return csr_from_coo(all_rows, all_cols, all_vals, (n, n))


def powerlaw_spd(n: int, alpha: float = 2.2, min_deg: int = 2,
                 max_deg: int | None = None, dominance: float = 1.2,
                 seed: int = 0, dtype=np.float64) -> CSRMatrix:
    """Power-law (skewed) degree SPD matrix — the sliced-ELL stress case.

    Off-diagonal degree of row i is drawn from a truncated Pareto
    (P(deg) ∝ deg^-alpha): most rows carry ``min_deg`` neighbors while a
    few hub rows carry up to ``max_deg``, so the global max row width W
    sits far above the mean and a global-W row-ELL layout pays padded
    work/bytes ∝ n·W ≫ nnz (the regime where SELL-C-σ slicing wins).
    Symmetrized and made diagonally dominant exactly like
    :func:`diag_dominant_spd`.
    """
    rng = np.random.default_rng(seed)
    max_deg = int(max_deg if max_deg is not None
                  else max(min_deg + 1, n // 4))
    u = rng.random(n)
    deg = np.floor(min_deg * u ** (-1.0 / (alpha - 1.0))).astype(np.int64)
    deg = np.clip(deg, min_deg, max_deg)
    rows = np.repeat(np.arange(n), deg)
    cols = rng.integers(0, n, size=rows.shape[0])
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    vals = rng.standard_normal(rows.shape[0])
    # Symmetrize: add the transpose triplets.
    rows_s = np.concatenate([rows, cols])
    cols_s = np.concatenate([cols, rows])
    vals_s = np.concatenate([vals, vals])
    a = csr_from_coo(rows_s, cols_s, vals_s.astype(dtype), (n, n))
    # Enforce diagonal dominance: diag = dominance * row abs-sum.
    row_ids = np.repeat(np.arange(n), a.row_nnz())
    abssum = np.bincount(row_ids, weights=np.abs(a.data), minlength=n)
    diag_rows = np.arange(n)
    diag_vals = dominance * np.maximum(abssum, 1e-8)
    all_rows = np.concatenate([row_ids, diag_rows])
    all_cols = np.concatenate([a.indices.astype(np.int64), diag_rows])
    all_vals = np.concatenate([a.data, diag_vals.astype(dtype)])
    return csr_from_coo(all_rows, all_cols, all_vals, (n, n))


def random_spd(n: int, cond: float = 1e4, seed: int = 0,
               dtype=np.float64) -> CSRMatrix:
    """Dense-backed SPD with an exactly controlled condition number.

    Only for small n (tests): A = Q diag(λ) Qᵀ with log-spaced λ in
    [1/cond, 1]; returned as CSR of the dense array.
    """
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.logspace(-np.log10(cond), 0, n)
    a = (q * lam) @ q.T
    a = (a + a.T) / 2
    rows, cols = np.nonzero(np.ones_like(a, dtype=bool))
    return csr_from_coo(rows, cols, a[rows, cols].astype(dtype), (n, n))


# name -> (factory, kwargs, paper_analogue)
_SUITE: Dict[str, Tuple[Callable[..., CSRMatrix], dict, str]] = {
    # Table 3 M1–M18 class: medium rows, structural / ill-conditioned.
    "tri_small":      (tridiagonal_spd, dict(n=4096), "ted_B (10.6k, easy)"),
    "struct_easy":    (diag_dominant_spd, dict(n=5000, nnz_per_row=40, dominance=2.0, seed=1), "cbuckle class"),
    "struct_hard":    (diag_dominant_spd, dict(n=5357, nnz_per_row=38, dominance=1.01, seed=2), "s3rmt3m3 class (hard)"),
    "struct_med":     (diag_dominant_spd, dict(n=17361, nnz_per_row=58, dominance=1.08, seed=3), "gyro_k class"),
    "poisson2d_64":   (poisson_2d, dict(nx=64), "small thermal"),
    "poisson2d_132":  (poisson_2d, dict(nx=132), "bodyy4 class (17.5k)"),
    "powerlaw_skew":  (powerlaw_spd, dict(n=4096, alpha=2.1, seed=5), "HBM-skew class (power-law degree)"),
    # Table 3 M19–M36 class: large rows, 2D/3D problems.
    "poisson2d_500":  (poisson_2d, dict(nx=500), "thermal mid (250k)"),
    "poisson2d_1000": (poisson_2d, dict(nx=1000), "ecology2 class (1.0M rows)"),
    "poisson3d_50":   (poisson_3d, dict(n_side=50), "offshore class (125k)"),
    "poisson3d_100":  (poisson_3d, dict(n_side=100), "Serena class (1.0M, 3D)"),
    "struct_large":   (diag_dominant_spd, dict(n=148770, nnz_per_row=70, dominance=1.1, seed=4), "bmwcra_1 class"),
}


def benchmark_suite(tier: str = "all") -> Dict[str, CSRMatrix]:
    """Materialize the named suite. tier ∈ {small, large, all}."""
    small = ["tri_small", "struct_easy", "struct_hard", "struct_med",
             "poisson2d_64", "poisson2d_132", "powerlaw_skew"]
    large = ["poisson2d_500", "poisson2d_1000", "poisson3d_50",
             "poisson3d_100", "struct_large"]
    names = {"small": small, "large": large, "all": small + large}[tier]
    return {k: _SUITE[k][0](**_SUITE[k][1]) for k in names}


def suite_metadata() -> Dict[str, str]:
    return {k: v[2] for k, v in _SUITE.items()}
