"""MatrixMarket (.mtx) I/O — SuiteSparse-compatible coordinate format.

Implemented natively (no scipy dependency in the data path) so the solver
stack is self-contained; handles ``real``/``integer`` + ``general``/
``symmetric`` coordinate headers, which covers the paper's whole Table 3.
"""
from __future__ import annotations

import gzip
import io
from pathlib import Path

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo

__all__ = ["read_mtx", "write_mtx"]


def _open(path: str | Path):
    path = Path(path)
    if path.suffix == ".gz":
        return io.TextIOWrapper(gzip.open(path, "rb"))
    return open(path, "r")


def read_mtx(path: str | Path, dtype=np.float64) -> CSRMatrix:
    with _open(path) as f:
        header = f.readline().strip().lower().split()
        if len(header) < 5 or header[0] != "%%matrixmarket" or header[1] != "matrix":
            raise ValueError(f"not a MatrixMarket matrix file: {path}")
        fmt, field, symmetry = header[2], header[3], header[4]
        if fmt != "coordinate":
            raise ValueError(f"only coordinate format supported, got {fmt}")
        if field not in ("real", "integer", "pattern"):
            raise ValueError(f"unsupported field {field}")
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        n_rows, n_cols, nnz = (int(t) for t in line.split())
        body = np.loadtxt(f, dtype=np.float64, ndmin=2, max_rows=nnz)
    if body.size == 0:
        body = np.zeros((0, 3))
    rows = body[:, 0].astype(np.int64) - 1
    cols = body[:, 1].astype(np.int64) - 1
    vals = body[:, 2].astype(dtype) if body.shape[1] > 2 else np.ones(rows.shape[0], dtype)
    if symmetry == "symmetric":
        off = rows != cols
        rows = np.concatenate([rows, cols[off]])
        cols = np.concatenate([cols, body[off, 0].astype(np.int64) - 1])
        vals = np.concatenate([vals, vals[off]])
    elif symmetry != "general":
        raise ValueError(f"unsupported symmetry {symmetry}")
    return csr_from_coo(rows, cols, vals, (n_rows, n_cols))


def write_mtx(path: str | Path, a: CSRMatrix, symmetric: bool = False) -> None:
    sym = "symmetric" if symmetric else "general"
    row_ids = np.repeat(np.arange(a.n_rows, dtype=np.int64), a.row_nnz())
    cols = a.indices.astype(np.int64)
    vals = a.data
    if symmetric:
        keep = row_ids >= cols  # store lower triangle
        row_ids, cols, vals = row_ids[keep], cols[keep], vals[keep]
    with open(path, "w") as f:
        f.write(f"%%MatrixMarket matrix coordinate real {sym}\n")
        f.write(f"{a.n_rows} {a.n_cols} {row_ids.shape[0]}\n")
        for r, c, v in zip(row_ids, cols, vals):
            f.write(f"{r + 1} {c + 1} {v:.17g}\n")
