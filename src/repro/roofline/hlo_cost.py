"""Loop-aware HLO cost walk — flops / HBM bytes / collective bytes.

``compiled.cost_analysis()`` counts every computation ONCE: a
scan-over-layers or microbatch loop body is weighted ×1 instead of
×trip_count, so a 64-layer model looks 64× cheaper than it is.  This
walker parses the post-SPMD HLO text and propagates **loop multiplicity**
(`backend_config={"known_trip_count":{"n":...}}`) through the call graph:

* **flops** — dot/convolution MACs ×2 (contraction size from operand
  shapes), elementwise arithmetic, reduces; transcendentals tallied
  separately;
* **HBM bytes** — post-fusion traffic model: each *top-level* op's
  operand+result bytes count; instructions inside a fusion are
  register-resident and count 0 (their flops still count);
* **collective wire bytes** — the :mod:`repro.roofline.hlo_bytes` per-op
  ring model, ×multiplicity.

The walk is exact on the module text — no model-shape assumptions — so
the §Roofline "useful fraction" (6·N·D / HLO flops) genuinely catches
remat and padding waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["CostWalk", "walk_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*(?://.*)?$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{}]+)\s*"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "remainder",
    "clamp", "floor", "ceil", "round-nearest-afz", "sign", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "atan2",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "expm1", "log1p", "erf",
                   "cbrt"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}
_NO_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
             "bitcast", "while", "conditional", "call", "after-all",
             "iota", "partition-id", "replica-id"}


def _shape_elems_bytes(sig: str) -> Tuple[int, int]:
    """(element count, bytes) over all tensors in a (possibly tuple) sig."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class _Instr:
    name: str
    shape_sig: str
    opcode: str
    rest: str            # operand list + attrs (raw tail of the line)
    elems: int
    bytes_: int


@dataclasses.dataclass
class CostWalk:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_count: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "CostWalk", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        self.collective_count += other.collective_count * mult
        for k, v in other.wire_by_kind.items():
            self.wire_by_kind[k] = self.wire_by_kind.get(k, 0) + v * mult


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                if line.startswith("ENTRY"):
                    entry = cur
                comps[cur] = []
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, sig, opcode, rest = m.groups()
        elems, nbytes = _shape_elems_bytes(sig)
        comps[cur].append(_Instr(name, sig, opcode, rest, elems, nbytes))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    frac = (g - 1) / g if g > 1 else 0.0
    kind = kind.replace("-start", "")
    if kind == "all-reduce":
        return 2 * frac * result_bytes
    if kind == "reduce-scatter":
        return frac * result_bytes * g
    if kind == "collective-permute":
        return float(result_bytes)
    return frac * result_bytes          # all-gather / all-to-all


_SLICY = {"dynamic-slice", "gather", "slice"}
#: zero-traffic pass-through ops the use-analysis traces through
_PASS = {"bitcast", "copy", "reshape", "transpose", "convert"}


def _param_effective_bytes(comp: List[_Instr], shapes: Dict[str, str]):
    """Effective read bytes per parameter index of a fused computation.

    A scan body's fusion takes the FULL stacked weight/cache tensor as
    operand but only dynamic-slices one layer out of it (possibly through
    bitcast/reshape chains) — the actual HBM read is the slice, not the
    stack.  For each parameter whose (traced) uses are all slice-like,
    return the summed slice-result bytes; if all uses are
    dynamic-update-slice *destinations*, return the update payload (the
    in-place write); otherwise None (= count the full operand).
    """
    params: Dict[int, str] = {}
    for ins in comp:
        if ins.opcode == "parameter":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                params[int(m.group(1))] = ins.name

    def operand_names(ins):
        return _OPERAND.findall(ins.rest.split(")")[0])

    def real_uses(pname):
        """Consumers of pname, traced through pass-through ops.
        Returns list of (instr, via_name)."""
        out = []
        frontier = [pname]
        seen = set()
        while frontier:
            nm = frontier.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for ins in comp:
                if nm in operand_names(ins):
                    if ins.opcode in _PASS:
                        frontier.append(ins.name)
                    else:
                        out.append((ins, nm))
        return out

    eff: Dict[int, Optional[int]] = {}
    for idx, pname in params.items():
        uses = real_uses(pname)
        if not uses:
            eff[idx] = None
            continue
        total = 0
        ok = True
        for u, via in uses:
            if u.opcode in _SLICY:
                total += u.bytes_                    # read: slice result
            elif (u.opcode == "dynamic-update-slice"
                  and operand_names(u) and operand_names(u)[0] == via):
                ops = operand_names(u)               # write: update payload
                if len(ops) >= 2 and ops[1] in shapes:
                    total += _shape_elems_bytes(shapes[ops[1]])[1]
                else:
                    total += u.bytes_ // 4           # conservative fallback
            else:
                ok = False
                break
        eff[idx] = total if ok else None
    return eff


def walk_hlo(text: str, *, default_group: int = 1,
             fusion_bytes_only: bool = True) -> CostWalk:
    comps = _parse_computations(text)
    memo: Dict[Tuple[str, bool], CostWalk] = {}
    eff_memo: Dict[str, Dict[int, Optional[int]]] = {}

    def shapes_in(comp: List[_Instr]) -> Dict[str, str]:
        return {i.name: i.shape_sig for i in comp}

    def fusion_read_bytes(called: str, operands: List[str],
                          shapes: Dict[str, str]) -> int:
        comp = comps.get(called, [])
        if called not in eff_memo:
            eff_memo[called] = _param_effective_bytes(
                comp, shapes_in(comp))
        eff = eff_memo[called]
        total = 0
        for i, opn in enumerate(operands):
            if opn not in shapes:
                continue
            full = _shape_elems_bytes(shapes[opn])[1]
            e = eff.get(i, None)
            total += full if e is None else min(e, full)
        return total

    def fusion_write_bytes(called: str, own_bytes: int) -> int:
        comp = comps.get(called, [])
        by_name = {i.name: i for i in comp}
        root = comp[-1] if comp else None
        # trace through pass-through ops to the real root producer
        hops = 0
        while root is not None and root.opcode in _PASS and hops < 8:
            ops = _OPERAND.findall(root.rest.split(")")[0])
            root = by_name.get(ops[0]) if ops else None
            hops += 1
        if root is not None and root.opcode == "dynamic-update-slice":
            ops = _OPERAND.findall(root.rest.split(")")[0])
            sh = shapes_in(comp)
            if len(ops) >= 2 and ops[1] in sh:
                return _shape_elems_bytes(sh[ops[1]])[1]
            return own_bytes // 4
        return own_bytes

    def cost_of(name: str, inside_fusion: bool) -> CostWalk:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        memo[key] = CostWalk()          # cycle guard
        comp = comps.get(name, [])
        shapes = shapes_in(comp)
        out = CostWalk()
        for ins in comp:
            op = ins.opcode
            operand_str = ins.rest.split(")")[0]
            operands = _OPERAND.findall(operand_str)
            # ---------- flops ----------
            if op == "dot":
                k = 1
                mc = _LHS_CONTRACT.search(ins.rest)
                if mc and operands and operands[0] in shapes:
                    lhs_dims = _SHAPE.search(shapes[operands[0]])
                    if lhs_dims:
                        dims = [int(d) for d in
                                lhs_dims.group(2).split(",") if d]
                        for ci in mc.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                out.flops += 2.0 * ins.elems * max(k, 1)
            elif op == "convolution":
                out.flops += 2.0 * ins.elems  # lower bound (rare here)
            elif op in _ELEMENTWISE:
                out.flops += ins.elems
            elif op in _TRANSCENDENTAL:
                out.transcendentals += ins.elems
                out.flops += ins.elems
            elif op == "reduce" or op == "reduce-window":
                opn = _OPERAND.search(ins.rest)
                if opn and opn.group(1) in shapes:
                    e, _ = _shape_elems_bytes(shapes[opn.group(1)])
                    out.flops += e
                else:
                    out.flops += ins.elems
            # ---------- bytes ----------
            if not inside_fusion and op not in _NO_BYTES:
                if op == "fusion":
                    m = _CALLS.search(ins.rest)
                    called = m.group(1) if m else ""
                    out.hbm_bytes += (
                        fusion_read_bytes(called, operands, shapes)
                        + fusion_write_bytes(called, ins.bytes_))
                elif op in _SLICY:
                    out.hbm_bytes += 2 * ins.bytes_      # read + write slice
                elif op == "dynamic-update-slice":
                    upd = (2 * _shape_elems_bytes(shapes[operands[1]])[1]
                           if len(operands) >= 2 and operands[1] in shapes
                           else ins.bytes_)
                    out.hbm_bytes += upd
                else:
                    opd_bytes = 0
                    for opn in operands:
                        if opn in shapes:
                            _, b = _shape_elems_bytes(shapes[opn])
                            opd_bytes += b
                    out.hbm_bytes += ins.bytes_ + opd_bytes
            # ---------- collectives ----------
            if op in _COLLECTIVES:
                g = _group_size(ins.rest, default_group)
                w = _wire_bytes(op, ins.bytes_, g)
                out.wire_bytes += w
                out.collective_count += 1
                kk = op.replace("-start", "")
                out.wire_by_kind[kk] = out.wire_by_kind.get(kk, 0) + w
            # ---------- called computations ----------
            if op == "fusion":
                m = _CALLS.search(ins.rest)
                if m:
                    out.add(cost_of(m.group(1),
                                    inside_fusion or fusion_bytes_only))
            elif op == "while":
                trip = 1
                mt = _TRIP.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc2 = _COND.search(ins.rest)
                if mb:
                    out.add(cost_of(mb.group(1), inside_fusion), trip)
                if mc2:
                    out.add(cost_of(mc2.group(1), inside_fusion), trip + 1)
            elif op == "conditional":
                mb = _BRANCHES.search(ins.rest)
                if mb:
                    branches = [b.strip().lstrip("%") for b in
                                mb.group(1).split(",") if b.strip()]
                    costs = [cost_of(b, inside_fusion) for b in branches]
                    if costs:               # max-cost branch executes
                        out.add(max(costs, key=lambda c: c.flops))
                else:
                    for attr in ("true_computation", "false_computation"):
                        m = re.search(attr + r"=%?([\w.\-]+)", ins.rest)
                        if m:
                            out.add(cost_of(m.group(1), inside_fusion), 0.5)
            elif op == "call":
                # A call is NOT a fusion: its callee's top-level fusions
                # still stream HBM (the CPU backend wraps loop bodies in
                # %parallel_* call shims) — keep the fusion flag as-is.
                m = _CALLS.search(ins.rest)
                if m and m.group(1) in comps:
                    out.add(cost_of(m.group(1), inside_fusion))
            elif op in ("custom-call", "reduce", "sort", "scatter",
                        "select-and-scatter", "map", "reduce-window"):
                m = _CALLS.search(ins.rest)
                if m and m.group(1) in comps and op != "custom-call":
                    # tiny scalar computations (add for reduce) — cheap but
                    # scale by output elems for map-like ops
                    sub = cost_of(m.group(1), True)
                    out.add(sub, max(ins.elems, 1)
                            if op in ("map",) else 1.0)
        memo[key] = out
        return out

    return cost_of("__entry__", False)
