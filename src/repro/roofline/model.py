"""Three-term roofline model over TPU v5e constants.

    compute    = HLO_FLOPs / peak_FLOP/s          (per device)
    memory     = HLO_bytes / HBM_bw               (per device)
    collective = wire_bytes / (links × link_bw)   (per device)

``cost_analysis()`` on the partitioned module already reports per-device
FLOPs/bytes, so no further division by chip count is needed; the
collective term divides by the ICI links a v5e chip drives (4, 2D torus).
MODEL_FLOPS = 6·N·D (dense; N_active for MoE) gives the useful-compute
ratio that catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

__all__ = ["V5E", "Hardware", "RooflineTerms", "roofline_terms",
           "model_flops_train", "model_flops_decode"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_bf16_flops: float       # per chip
    hbm_bw: float                # bytes/s per chip
    ici_link_bw: float           # bytes/s per link
    ici_links: int               # links per chip
    hbm_bytes: float             # capacity per chip

    def peak_flops(self, dtype: str = "bf16") -> float:
        scale = {"bf16": 1.0, "f32": 0.5, "fp32": 0.5,
                 "f64": 1 / 400, "fp64": 1 / 400}.get(dtype, 1.0)
        return self.peak_bf16_flops * scale


#: TPU v5e (assignment constants: 197 TFLOP/s bf16, 819 GB/s HBM,
#: ~50 GB/s/link ICI; fp64 is software-emulated — documented assumption).
V5E = Hardware(name="tpu_v5e", peak_bf16_flops=197e12, hbm_bw=819e9,
               ici_link_bw=50e9, ici_links=4, hbm_bytes=16e9)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    wire_bytes: float            # per-device collective bytes
    model_flops: Optional[float] = None   # 6·N·D useful flops (global)
    chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline step time (max of the three overlappable terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / (chips × HLO_FLOPs): how much compiled compute is
        useful (remat/padding/redundancy show up here)."""
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / (self.chips * self.flops)

    @property
    def mfu_at_roofline(self) -> Optional[float]:
        """Model FLOPs utilization if the step ran at its roofline bound."""
        if self.model_flops is None or self.bound_s == 0:
            return None
        per_chip = self.model_flops / self.chips
        return per_chip / (self.bound_s * V5E.peak_bf16_flops)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes, "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "mfu_at_roofline": self.mfu_at_roofline, "chips": self.chips,
        }


def roofline_terms(cost: Dict, wire_bytes: float, *, hw: Hardware = V5E,
                   dtype: str = "bf16", chips: int = 1,
                   model_flops: Optional[float] = None) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / hw.peak_flops(dtype),
        memory_s=hbm / hw.hbm_bw,
        collective_s=wire_bytes / (hw.ici_links * hw.ici_link_bw),
        flops=flops, hbm_bytes=hbm, wire_bytes=wire_bytes,
        model_flops=model_flops, chips=chips)


def model_flops_train(n_params: int, n_tokens: int) -> float:
    """6·N·D — fwd+bwd useful flops for one step over n_tokens."""
    return 6.0 * n_params * n_tokens


def model_flops_decode(n_params: int, batch: int) -> float:
    """2·N per generated token (fwd only), × batch."""
    return 2.0 * n_params * batch
