"""Roofline analysis: collective parsing + 3-term model + report."""
from repro.roofline.hlo_bytes import (CollectiveOp, collective_bytes,
                                      parse_collectives)
from repro.roofline.model import (V5E, Hardware, RooflineTerms,
                                  model_flops_decode, model_flops_train,
                                  roofline_terms)
from repro.roofline.report import format_table, load_results, one_liner

__all__ = ["CollectiveOp", "collective_bytes", "parse_collectives",
           "V5E", "Hardware", "RooflineTerms", "roofline_terms",
           "model_flops_train", "model_flops_decode", "format_table",
           "load_results", "one_liner"]
