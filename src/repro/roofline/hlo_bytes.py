"""Collective-byte accounting from compiled (post-SPMD) HLO text.

``cost_analysis()`` has no collective term, so we parse the partitioned
module: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` instruction carries its result
shape inline, e.g.::

    %all-reduce.3 = f32[64,256]{1,0} all-reduce(%dot), replica_groups=...

Per-op bytes-moved-per-device model (ring algorithms, the standard cost):

  =====================  ==========================================
  op                     bytes on the wire per device
  =====================  ==========================================
  all-gather             (g−1)/g · result_bytes   (receives all shards)
  reduce-scatter         (g−1)/g · operand_bytes ≈ (g−1)/g · g·result
  all-reduce             2 · (g−1)/g · result_bytes (RS + AG)
  all-to-all             (g−1)/g · result_bytes
  collective-permute     result_bytes
  =====================  ==========================================

where g = replica-group size parsed from ``replica_groups``.  Tuple-shaped
collectives (variadic all-reduce) sum their element shapes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

__all__ = ["CollectiveOp", "parse_collectives", "collective_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    kind: str
    result_bytes: int       # per-device result payload
    group_size: int
    wire_bytes: int         # modeled bytes on the wire per device


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:                                   # iota form [groups, size]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:                                   # explicit {{0,1,2,...},...}
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def parse_collectives(hlo_text: str,
                      default_group: int = 1) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        rb = _shape_bytes(sig)
        g = _group_size(line, default_group)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            wire = int(2 * frac * rb)
        elif kind == "reduce-scatter":
            wire = int(frac * rb * g)       # operand = g × result
        elif kind == "collective-permute":
            wire = rb
        else:                               # all-gather / all-to-all
            wire = int(frac * rb)
        ops.append(CollectiveOp(kind, rb, g, wire))
    return ops


def collective_bytes(hlo_text: str,
                     default_group: int = 1) -> Dict[str, float]:
    """Aggregate per-device collective traffic from compiled HLO text."""
    ops = parse_collectives(hlo_text, default_group)
    by_kind: Dict[str, float] = {}
    for op in ops:
        by_kind[op.kind] = by_kind.get(op.kind, 0) + op.wire_bytes
    return {
        "total_wire_bytes": float(sum(o.wire_bytes for o in ops)),
        "n_ops": len(ops),
        "by_kind": by_kind,
    }
