"""Roofline report — render dry-run JSON artifacts into the §Roofline table."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

__all__ = ["load_results", "format_table", "one_liner"]


def load_results(artifact_dir: str) -> List[Dict]:
    out = []
    if not os.path.isdir(artifact_dir):
        return out
    for f in sorted(os.listdir(artifact_dir)):
        if f.endswith(".json"):
            with open(os.path.join(artifact_dir, f)) as fh:
                out.append(json.load(fh))
    return out


def _fmt_s(x: Optional[float]) -> str:
    return "-" if x is None else f"{x:.3e}"


def _fmt_pct(x: Optional[float]) -> str:
    return "-" if x is None else f"{100 * x:.1f}%"


def format_table(results: List[Dict]) -> str:
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'compute_s':9s} | "
           f"{'memory_s':9s} | {'collect_s':9s} | {'bound':10s} | "
           f"{'useful':7s} | {'MFU@roof':8s} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    rows = [hdr, sep]
    for r in results:
        t = r.get("roofline", {})
        rows.append(
            f"| {r['arch']:24s} | {r['shape']:11s} | "
            f"{_fmt_s(t.get('compute_s')):9s} | "
            f"{_fmt_s(t.get('memory_s')):9s} | "
            f"{_fmt_s(t.get('collective_s')):9s} | "
            f"{t.get('dominant', '-'):10s} | "
            f"{_fmt_pct(t.get('useful_fraction')):7s} | "
            f"{_fmt_pct(t.get('mfu_at_roofline')):8s} |")
    return "\n".join(rows)


def one_liner(r: Dict) -> str:
    t = r.get("roofline", {})
    dom = t.get("dominant", "?")
    hints = {
        "compute": "reduce recompute/padding or shift flops to bf16",
        "memory": "fuse more, cut activation width, or raise arithmetic "
                  "intensity (bigger microbatch per sweep)",
        "collective": "reshard to shrink the gathered dim, overlap with "
                      "compute, or move the reduction off the critical path",
    }
    return (f"{r['arch']} × {r['shape']}: {dom}-bound "
            f"(bound {_fmt_s(max(t.get('compute_s', 0), t.get('memory_s', 0), t.get('collective_s', 0)))}s) — "
            f"{hints.get(dom, '')}")
