"""Training launcher — ``--arch <id> --optimizer adamw|cggn``.

Smoke scale (CPU, reduced config) by default; ``--full`` selects the
published config (real-cluster scale — the multi-pod dry-run validates
those shapes compile; this driver is the same code path).

Example::

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --steps 50 --seq-len 128 --batch 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.models.api import forward_logits
from repro.train import (AdamWConfig, CGGNConfig, DataConfig, SyntheticLM,
                         Trainer, TrainerConfig, adamw_init, cggn_init,
                         cggn_update, make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--optimizer", choices=["adamw", "cggn"],
                    default="adamw")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="published config (cluster scale) instead of the "
                         "reduced smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} family={cfg.family} "
          f"~{cfg.param_count() / 1e6:.1f}M params")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                  global_batch=args.batch, seed=args.seed))

    if args.optimizer == "adamw":
        opt = AdamWConfig(lr=args.lr)
        step_fn = make_train_step(cfg, opt=opt,
                                  microbatches=args.microbatches)
        trainer = Trainer(cfg, data, step_fn, params,
                          adamw_init(params, opt),
                          TrainerConfig(total_steps=args.steps,
                                        ckpt_every=args.ckpt_every,
                                        ckpt_dir=args.ckpt_dir))
        log = trainer.run()
    else:
        ccfg = CGGNConfig(cg_iters=8, scheme="tpu_fp32", lr=1.0)
        state = cggn_init(params, key)
        log = []
        for step in range(args.steps):
            batch = data.batch_at(step)

            def logits_fn(p):
                return forward_logits(p, cfg, batch)

            def loss_logits(lg):
                lse = jax.nn.logsumexp(lg, axis=-1)
                picked = jnp.take_along_axis(
                    lg, batch["labels"][..., None], axis=-1)[..., 0]
                return jnp.mean(lse - picked)

            def vag(p):
                return jax.value_and_grad(
                    lambda q: loss_logits(logits_fn(q)))(p)

            params, state, m = cggn_update(
                params, state, loss_logits_fn=loss_logits,
                logits_fn=logits_fn, loss_value_and_grad=vag, cfg=ccfg)
            log.append({"step": step, "loss": float(m["loss"])})
            if step % 5 == 0:
                print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                      f"|δ| {float(m['delta_norm']):.3f}")

    print(f"final loss: {log[-1]['loss']:.4f}")
    return log


if __name__ == "__main__":
    main()
