import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run — proves the distribution config is coherent.

For every (architecture × input shape) cell, on the single-pod (16×16)
and multi-pod (2×16×16) production meshes::

    lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
    compiled = lowered.compile()
    compiled.memory_analysis()    # proves it fits
    compiled.cost_analysis()      # FLOPs/bytes for §Roofline

plus the collective-byte parse of the partitioned HLO.  Results land as
JSON artifacts under ``experiments/dryrun/<mesh>/`` which
``benchmarks``/EXPERIMENTS.md consume.

Usage::

    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single
    python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable, cells, get_config, input_specs
from repro.distributed.hints import sharding_hints
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        named_shardings, param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.api import decode_step, forward_logits, init_params, loss_fn
from repro.roofline.hlo_cost import walk_hlo
from repro.roofline.model import (V5E, model_flops_decode, model_flops_train,
                                  roofline_terms)
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

#: grad-accumulation microbatches for the train shape (memory feasibility:
#: 1 sequence / device / microbatch at global_batch=256 on a 16×16 mesh).
TRAIN_MICROBATCHES = 16

#: §Perf hillclimb switches (comma-separated in REPRO_DRYRUN_OPTS):
#:   bf16_gather — cast ≥2-D params to bf16 ONCE per step before the
#:                 microbatch scan: FSDP all-gathers and weight reads move
#:                 half the bytes (Mix-V3's "stream the operator low, keep
#:                 the iterate high" applied to training weights);
#:   ssd_chunk64 / ssd_chunk128 — SSD chunk length override (the
#:                 chunk-quadratic intra term scales ~linearly with q).
OPTS = frozenset(o for o in os.environ.get(
    "REPRO_DRYRUN_OPTS", "").split(",") if o)


def _params_shape(cfg):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


def _opt_shape(params_shape, opt):
    return jax.eval_shape(partial(adamw_init, cfg=opt), params_shape)


def build_cell(arch: str, shape_name: str, mesh):
    """(fn, example_args, in_shardings, out_shardings, donate) per cell."""
    cfg = get_config(arch)
    if cfg.ssm is not None:
        import dataclasses as _dc
        if "ssd_chunk64" in OPTS:
            cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=64))
        elif "ssd_chunk128" in OPTS:
            cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=128))
    shape = SHAPES[shape_name]
    specs = input_specs(arch, shape_name)
    pshape = _params_shape(cfg)
    pspecs = param_specs(pshape, mesh)
    p_sh = named_shardings(pspecs, mesh)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = AdamWConfig()
        oshape = _opt_shape(pshape, opt)
        o_sh = type(oshape)(step=rep, m=named_shardings(pspecs, mesh),
                            v=named_shardings(pspecs, mesh))
        b_sh = named_shardings(batch_specs(specs, mesh), mesh)
        mb = TRAIN_MICROBATCHES

        from repro.distributed.sharding import data_axes
        dp = data_axes(mesh)

        def train_step(params, opt_state, batch, step):
            def split(x):
                # strided split: each microbatch spans ALL data shards
                # (a contiguous reshape would put a whole microbatch on
                # one shard and serialize the accumulation)
                y = x.reshape(x.shape[0] // mb, mb,
                              *x.shape[1:]).swapaxes(0, 1)
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(
                        mesh, P(None, dp, *([None] * (x.ndim - 1)))))
            micros = jax.tree_util.tree_map(split, batch)

            if "bf16_gather" in OPTS:
                # Cast params to bf16 *while still FSDP-sharded* (the
                # sharding constraint pins the convert before the gather —
                # without it XLA gathers fp32 and converts after): every
                # FSDP all-gather and weight read in the microbatch scan
                # moves half the bytes.  Grads flow w.r.t. the bf16 view;
                # fp32 masters update in adamw (Mix-V3's "stream the
                # operator low, keep the iterate high" applied to weights).
                fwd_params = jax.tree_util.tree_map(
                    lambda p, sh: jax.lax.with_sharding_constraint(
                        p.astype(jnp.bfloat16), sh)
                    if p.ndim >= 2 and p.dtype == jnp.float32 else p,
                    params, p_sh)
                # barrier pins convert-before-gather (XLA otherwise hoists
                # the convert past the FSDP all-gather, moving f32)
                fwd_params = jax.lax.optimization_barrier(fwd_params)
            else:
                fwd_params = params

            def accum(carry, micro):
                l, g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, micro))(fwd_params)
                g = jax.tree_util.tree_map(
                    lambda a, z: a.astype(z.dtype), g, carry[1])
                return (carry[0] + l,
                        jax.tree_util.tree_map(jnp.add, carry[1], g)), None

            zero = jax.tree_util.tree_map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), sh), params, p_sh)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero), micros)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            new_p, new_o = adamw_update(grads, opt_state, params, opt,
                                        lr=jnp.asarray(3e-4, jnp.float32))
            return new_p, new_o, loss / mb

        args = (pshape, oshape, specs, jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_sh, o_sh, b_sh, rep)
        out_sh = (p_sh, o_sh, rep)
        # donate params+opt: in-place update (ping-pong aliasing)
        return train_step, args, in_sh, out_sh, (0, 1)

    if shape.kind == "prefill":
        b_sh = named_shardings(batch_specs(specs, mesh), mesh)

        def prefill_step(params, batch):
            # serving prefill: only the last position's logits materialize
            return forward_logits(params, cfg, batch, last_only=True)[:, 0]

        return (prefill_step, (pshape, specs), (p_sh, b_sh),
                NamedSharding(mesh, P(("data",), None)), ())

    # decode
    c_sh = named_shardings(
        cache_specs(specs["cache"], mesh, batch=shape.global_batch), mesh)
    tok_spec = (NamedSharding(mesh, P(("data",)))
                if shape.global_batch % mesh.shape.get("data", 1) == 0
                else rep)

    def serve_step(params, cache, token, pos):
        logits, new_cache = decode_step(params, cfg, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    args = (pshape, specs["cache"], specs["token"], specs["pos"])
    in_sh = (p_sh, c_sh, tok_spec, rep)
    out_sh = (tok_spec, c_sh)
    # donate the cache: the update aliases in place (double-channel
    # ping-pong analogue; halves decode HBM footprint)
    return serve_step, args, in_sh, out_sh, (1,)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="SKIP", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(arch, shape_name, mesh)
    with sharding_hints(mesh):          # activation hints trace-time active
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    xla_cost = dict(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "total_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }
    hlo = compiled.as_text()
    # Loop-multiplicity-aware walk (xla cost_analysis counts scan bodies
    # once — useless for 64-layer models; see roofline/hlo_cost.py).
    w = walk_hlo(hlo, default_group=chips)
    cost = {"flops": w.flops, "bytes accessed": w.hbm_bytes,
            "transcendentals": w.transcendentals}
    coll = {"total_wire_bytes": w.wire_bytes,
            "n_ops": w.collective_count, "by_kind": w.wire_by_kind}

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        mf = model_flops_train(n_active,
                               shape.global_batch * shape.seq_len)
    elif shape.kind == "prefill":
        mf = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        mf = model_flops_decode(n_active, shape.global_batch)

    terms = roofline_terms(cost, coll["total_wire_bytes"], chips=chips,
                           model_flops=mf)
    rec.update(
        status="OK",
        kind=shape.kind,
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        cost=cost,
        xla_cost={k: xla_cost.get(k) for k in
                  ("flops", "bytes accessed", "transcendentals")
                  if k in xla_cost},
        memory=mem,
        fits_hbm=mem["total_bytes"] <= V5E.hbm_bytes,
        collectives=coll,
        roofline=terms.as_dict(),
    )
    if save:
        d = os.path.join(ART_DIR, mesh_kind)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process (isolation)")
    args = ap.parse_args(argv)

    if args.all:
        results = []
        for arch, shape_name, ok, why in cells():
            if args.subprocess and ok:
                import subprocess
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--mesh", args.mesh]
                r = subprocess.run(cmd, capture_output=True, text=True)
                status = "OK" if r.returncode == 0 else "FAIL"
                print(f"{arch:24s} {shape_name:12s} {status}")
                if r.returncode != 0:
                    print(r.stdout[-2000:], r.stderr[-2000:])
                continue
            try:
                rec = run_cell(arch, shape_name, args.mesh)
            except Exception as e:                        # noqa: BLE001
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": args.mesh, "status": "FAIL",
                       "error": f"{type(e).__name__}: {e}"}
                traceback.print_exc()
            results.append(rec)
            t = rec.get("roofline", {})
            print(f"{arch:24s} {shape_name:12s} {rec['status']:4s} "
                  f"compile={rec.get('compile_s', '-')}s "
                  f"dom={t.get('dominant', '-')}")
        n_fail = sum(1 for r in results if r["status"] == "FAIL")
        print(f"\n{len(results)} cells: "
              f"{sum(1 for r in results if r['status'] == 'OK')} OK, "
              f"{sum(1 for r in results if r['status'] == 'SKIP')} SKIP, "
              f"{n_fail} FAIL")
        sys.exit(1 if n_fail else 0)

    rec = run_cell(args.arch, args.shape, args.mesh)
    print(json.dumps({k: v for k, v in rec.items() if k != "collectives"},
                     indent=1))
    if rec["status"] == "FAIL":
        sys.exit(1)


if __name__ == "__main__":
    main()
