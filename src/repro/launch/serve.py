"""Serving launcher — batched decode over the slot engine.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --requests 6 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import DecodeEngine, EngineConfig, bytes_per_slot


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"arch={cfg.name}  cache bytes/slot@{args.max_len}: "
          f"{bytes_per_slot(cfg, args.max_len):,}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = DecodeEngine(cfg, params, EngineConfig(
        batch_slots=args.slots, max_len=args.max_len,
        temperature=args.temperature, cache_dtype="float32", seed=args.seed))

    rng = np.random.default_rng(args.seed)
    pending = [list(rng.integers(1, cfg.vocab, size=rng.integers(3, 10)))
               for _ in range(args.requests)]
    done, t0, ticks = [], time.monotonic(), 0
    audio = None
    if cfg.encoder is not None:
        import jax.numpy as jnp
        audio = jnp.zeros((cfg.encoder.n_ctx, cfg.d_model))

    while pending or eng.active.any():
        while pending and (~eng.active).any():
            prompt = pending.pop()
            s = eng.add_request([int(t) for t in prompt],
                                max_new=args.max_new, audio_embeds=audio)
            print(f"  admitted slot {s} (prompt {len(prompt)} tokens)")
        out = eng.step()
        ticks += 1
        for s in list(out):
            if not eng.active[s]:
                done.append((s, eng.outputs[s]))
                print(f"  slot {s} done: {len(eng.outputs[s])} tokens")
    dt = time.monotonic() - t0
    total = sum(len(o) for _, o in done)
    print(f"{len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, {ticks} ticks)")


if __name__ == "__main__":
    main()
