"""Production mesh factory.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Shapes: one v5e pod = 256 chips as
(data=16, model=16); two pods = 512 chips with a leading DCN-attached
``pod`` axis carrying only data parallelism.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 2) on 4 CPU devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
