"""Linear operators consumed by the JPCG solver.

Every operator exposes ``matvec`` (the SpMV, with the precision scheme's
casts applied *inside*), ``diag`` (Jacobi preconditioner source), and ``n``.
Concrete operators:

* :class:`BellOperator` — banked-ELL sparse matrix on device (the production
  path; same dataflow as the Pallas kernel, pure-jnp/XLA execution).
* :class:`DenseOperator` — small dense SPD matrices (tests).
* :class:`CallableOperator` — matrix-free (the CGGN optimizer's GGN-vector
  product plugs in here).

Mixed-precision contract (paper §6): the operator *stores* A at
``scheme.matrix_dtype``; ``matvec`` casts the incoming vector to
``scheme.spmv_in_dtype`` (a true rounding — this is where Mix-V1/V2 lose
information), multiplies/accumulates at ``scheme.spmv_acc_dtype``, and
returns at ``scheme.vector_dtype``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import PrecisionScheme, get_scheme
from repro.sparse.bell import BellMatrix, csr_to_bell
from repro.sparse.csr import CSRMatrix

__all__ = ["BellOperator", "DenseOperator", "CallableOperator", "as_operator",
           "bell_spmv_jnp"]


def bell_spmv_jnp(tile_cols: jax.Array, vals: jax.Array, local_rows: jax.Array,
                  local_cols: jax.Array, x_pad: jax.Array, *,
                  block_rows: int, col_tile: int,
                  scheme: PrecisionScheme) -> jax.Array:
    """Banked-ELL SpMV, pure jnp (the XLA backend; also the kernel oracle).

    ``x_pad`` has length ``n_col_tiles * col_tile``; returns a vector of
    length ``n_row_blocks * block_rows`` at ``scheme.vector_dtype``.
    """
    B, T, L = vals.shape
    acc = scheme.spmv_acc_dtype
    x_in = x_pad.astype(scheme.spmv_in_dtype)           # the Mix-V1/V2 rounding
    x_tiles = x_in.reshape(-1, col_tile)[tile_cols]     # [B, T, C] tile gather
    x_g = jnp.take_along_axis(x_tiles, local_cols, axis=-1)   # [B, T, L]
    prod = vals.astype(acc) * x_g.astype(acc)
    seg = (jnp.arange(B, dtype=jnp.int32)[:, None, None] * block_rows
           + local_rows).reshape(-1)
    y = jax.ops.segment_sum(prod.reshape(-1), seg,
                            num_segments=B * block_rows)
    return y.astype(scheme.vector_dtype)


@dataclasses.dataclass(frozen=True)
class BellOperator:
    """Banked-ELL matrix resident on device at the scheme's matrix dtype."""

    tile_cols: jax.Array   # int32[B, T]
    vals: jax.Array        # matrix_dtype[B, T, L]
    local_rows: jax.Array  # int32[B, T, L]
    local_cols: jax.Array  # int32[B, T, L]
    diag: jax.Array        # vector_dtype[n]
    n: int
    block_rows: int
    col_tile: int
    padded_cols: int
    scheme: PrecisionScheme
    nnz: int

    @classmethod
    def from_bell(cls, m: BellMatrix, scheme, diag: np.ndarray) -> "BellOperator":
        scheme = get_scheme(scheme)
        return cls(
            tile_cols=jnp.asarray(m.tile_cols),
            vals=jnp.asarray(m.vals).astype(scheme.matrix_dtype),
            local_rows=jnp.asarray(m.local_rows),
            local_cols=jnp.asarray(m.local_cols),
            diag=jnp.asarray(diag).astype(scheme.vector_dtype),
            n=m.shape[0], block_rows=m.block_rows, col_tile=m.col_tile,
            padded_cols=m.padded_cols, scheme=scheme, nnz=m.nnz)

    def matvec(self, x: jax.Array) -> jax.Array:
        x_pad = jnp.zeros(self.padded_cols, dtype=x.dtype).at[: self.n].set(x)
        y = bell_spmv_jnp(self.tile_cols, self.vals, self.local_rows,
                          self.local_cols, x_pad, block_rows=self.block_rows,
                          col_tile=self.col_tile, scheme=self.scheme)
        return y[: self.n]

    def flops_per_matvec(self) -> int:
        return 2 * self.nnz


@dataclasses.dataclass(frozen=True)
class DenseOperator:
    a: jax.Array           # matrix_dtype[n, n]
    diag: jax.Array        # vector_dtype[n]
    scheme: PrecisionScheme

    @classmethod
    def from_dense(cls, a: np.ndarray, scheme) -> "DenseOperator":
        scheme = get_scheme(scheme)
        return cls(a=jnp.asarray(a).astype(scheme.matrix_dtype),
                   diag=jnp.asarray(np.diag(np.asarray(a))).astype(scheme.vector_dtype),
                   scheme=scheme)

    @property
    def n(self) -> int:
        return int(self.a.shape[0])

    def matvec(self, x: jax.Array) -> jax.Array:
        acc = self.scheme.spmv_acc_dtype
        x_in = x.astype(self.scheme.spmv_in_dtype)
        y = self.a.astype(acc) @ x_in.astype(acc)
        return y.astype(self.scheme.vector_dtype)

    def flops_per_matvec(self) -> int:
        return 2 * self.n * self.n


@dataclasses.dataclass(frozen=True)
class CallableOperator:
    """Matrix-free operator: fn must map vector_dtype -> vector_dtype."""

    fn: Callable[[jax.Array], jax.Array]
    diag: jax.Array
    n: int
    scheme: PrecisionScheme

    def matvec(self, x: jax.Array) -> jax.Array:
        x_in = x.astype(self.scheme.spmv_in_dtype)
        return self.fn(x_in).astype(self.scheme.vector_dtype)

    def flops_per_matvec(self) -> int:
        return 0  # unknown for matrix-free


# Register operators as pytrees so they can be passed straight into jitted
# solvers (arrays are leaves; sizes/scheme are static metadata, so one
# compiled solver is reused across every matrix with the same padded bucket
# — the paper's "arbitrary problem without re-synthesis" goal).
jax.tree_util.register_dataclass(
    BellOperator,
    data_fields=["tile_cols", "vals", "local_rows", "local_cols", "diag"],
    meta_fields=["n", "block_rows", "col_tile", "padded_cols", "scheme", "nnz"])
jax.tree_util.register_dataclass(
    DenseOperator, data_fields=["a", "diag"], meta_fields=["scheme"])
jax.tree_util.register_dataclass(
    CallableOperator, data_fields=["diag"], meta_fields=["fn", "n", "scheme"])


def as_operator(a, scheme, *, diag=None, n=None, block_rows: int = 256,
                col_tile: int = 512):
    """Coerce a CSRMatrix / BellMatrix / dense array / callable to an operator."""
    scheme = get_scheme(scheme)
    if isinstance(a, (BellOperator, DenseOperator, CallableOperator)):
        return a
    if isinstance(a, CSRMatrix):
        d = a.diagonal() if diag is None else diag
        bell = csr_to_bell(a, block_rows=block_rows, col_tile=col_tile)
        return BellOperator.from_bell(bell, scheme, d)
    if isinstance(a, BellMatrix):
        if diag is None:
            raise ValueError("BellMatrix input requires an explicit diag")
        return BellOperator.from_bell(a, scheme, diag)
    if callable(a):
        if diag is None or n is None:
            raise ValueError("callable operator requires diag and n")
        return CallableOperator(fn=a, diag=jnp.asarray(diag).astype(
            scheme.vector_dtype), n=n, scheme=scheme)
    arr = np.asarray(a)
    if arr.ndim == 2:
        return DenseOperator.from_dense(arr, scheme)
    raise TypeError(f"cannot build an operator from {type(a)}")
