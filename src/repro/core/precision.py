"""Mixed-precision schemes for the JPCG SpMV (paper §6, Table 1).

The paper's schemes, at the *faithful* (FP64-host) tier:

  ============  ======  ======  ======
  scheme        A       x_in    y_out
  ============  ======  ======  ======
  fp64          FP64    FP64    FP64
  mixed_v1      FP32    FP32    FP32
  mixed_v2      FP32    FP32    FP64
  mixed_v3      FP32    FP64    FP64   <- Callipepla's choice
  ============  ======  ======  ======

Main-loop vectors are *always* kept at ``vector_dtype`` (FP64 at this tier),
exactly as the paper mandates ("we always maintain the vectors in the main
loop in FP64").

TPU v5e has no native FP64 ALUs (emulation is ~2 orders of magnitude slower
than fp32), so the production tier shifts every scheme down one level:
fp64→fp32 and fp32→bf16.  The byte-ratio economics that motivate Mix-V3
(matrix value stream is half-width, vectors full-width) are identical at the
lower tier, which is the hardware-adaptation argument recorded in DESIGN.md.

  ============  ======  ======  ======
  scheme        A       x_in    y_out   (vector_dtype = fp32)
  ============  ======  ======  ======
  tpu_fp32      FP32    FP32    FP32
  tpu_v1        BF16    BF16    BF16
  tpu_v2        BF16    BF16    FP32
  tpu_v3        BF16    FP32    FP32   <- Callipepla's choice, TPU tier
  ============  ======  ======  ======
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["PrecisionScheme", "get_scheme", "SCHEMES"]


@dataclasses.dataclass(frozen=True)
class PrecisionScheme:
    name: str
    matrix_dtype: jnp.dtype    # storage dtype of A's nonzero values
    spmv_in_dtype: jnp.dtype   # x as consumed by the SpMV
    spmv_acc_dtype: jnp.dtype  # multiply/accumulate dtype inside the SpMV
    vector_dtype: jnp.dtype    # main-loop vectors (r, p, x, z, ap) and scalars

    @property
    def matrix_bytes(self) -> int:
        return jnp.dtype(self.matrix_dtype).itemsize

    @property
    def vector_bytes(self) -> int:
        return jnp.dtype(self.vector_dtype).itemsize

    def nonzero_stream_bytes(self, index_bytes: int = 2) -> int:
        """Bytes per nonzero in the matrix stream (value + 1 column index).

        This mirrors the layouts actually in use: the stacked row-ELL /
        sliced-ELL slots each hold one value at ``matrix_dtype`` plus
        one *local* column index — int16 whenever the bucketed row count
        stays under 2^15 (the default here), int32 beyond.  Pass the
        real width via ``index_bytes=``
        :func:`repro.sparse.stacking.index_bytes_for`; padding overheads
        are measured, not modeled (``stream_bytes_per_nnz()`` on the
        stacked arrays).  The paper's Challenge-3 arithmetic had
        2 packed indices per nonzero (Serpens 64-bit words); our
        row-identity is the lane position, so the second index is free.
        """
        return self.matrix_bytes + index_bytes


_f64, _f32, _bf16 = jnp.float64, jnp.float32, jnp.bfloat16

SCHEMES = {
    # Faithful tier (validated on CPU with jax_enable_x64).
    "fp64":     PrecisionScheme("fp64",     _f64,  _f64,  _f64, _f64),
    "mixed_v1": PrecisionScheme("mixed_v1", _f32,  _f32,  _f32, _f64),
    "mixed_v2": PrecisionScheme("mixed_v2", _f32,  _f32,  _f64, _f64),
    "mixed_v3": PrecisionScheme("mixed_v3", _f32,  _f64,  _f64, _f64),
    # TPU-native tier (one level down; vector_dtype fp32).
    "tpu_fp32": PrecisionScheme("tpu_fp32", _f32,  _f32,  _f32, _f32),
    "tpu_v1":   PrecisionScheme("tpu_v1",   _bf16, _bf16, _bf16, _f32),
    "tpu_v2":   PrecisionScheme("tpu_v2",   _bf16, _bf16, _f32, _f32),
    "tpu_v3":   PrecisionScheme("tpu_v3",   _bf16, _f32,  _f32, _f32),
}


def get_scheme(name_or_scheme) -> PrecisionScheme:
    if isinstance(name_or_scheme, PrecisionScheme):
        return name_or_scheme
    try:
        return SCHEMES[name_or_scheme]
    except KeyError:
        raise ValueError(
            f"unknown precision scheme {name_or_scheme!r}; "
            f"available: {sorted(SCHEMES)}") from None
