"""Batched multi-system JPCG — B independent solves in ONE compiled loop.

The paper's Challenge 1 is "support an arbitrary problem and terminate
acceleration processing on the fly"; the serving-scale version of that
challenge is *many* arbitrary problems at once.  This module stacks B
independent SPD systems along a leading batch axis and solves them inside
one ``lax.while_loop`` through one of two engines:

* ``engine="vm"`` (default) — the batched stream VM
  (:mod:`repro.core.vm`) executing a compiled stream-ISA program
  (:func:`repro.core.compile.compile_policy`); ``policy=`` picks the VSR
  schedule ("paper" | "min_traffic") and ``program=`` injects any custom
  program.  By default the program is *specialized* into the executable
  at trace time (straight-line ops, cached per (bucket, backend, scheme,
  program bytes) — the fast path); ``specialize=False`` keeps the
  program a traced operand so the executable is cached per (bucket
  shape, backend, scheme) — **never** per program/policy — and swapping
  schedules never recompiles (the paper's
  one-bitstream-serves-any-schedule goal, kept where it matters).
* ``engine="phases"`` — the phase-fused loop
  (:func:`repro.core.phases.vsr_iteration`, literally the single-system
  iteration code), kept as the bit-exact oracle the VM is tested against.

Either engine runs the same masked per-lane loop:

* every lane carries its own ``active`` flag; a lane terminates on the
  fly at its own ``‖r‖² ≤ τ_g`` while the batch keeps iterating — its
  ``x/r/p`` freeze (masked update) and only the live lanes pay for new
  iterations being *observed* (the frozen lanes' arithmetic is dead
  compute on a SIMD machine either way, exactly like frozen decode slots
  in :class:`repro.serve.engine.DecodeEngine`);
* the loop exits when every lane is done or ``maxiter`` is reached.

Batch API
---------
>>> from repro.core.batch import jpcg_solve_batched
>>> results = jpcg_solve_batched([a1, a2, ...], tol=1e-12)
>>> results[0].x, results[0].iterations, results[0].converged

``problems`` is a sequence of :class:`~repro.sparse.csr.CSRMatrix` (or
square dense arrays); ``bs``/``x0s`` optionally give per-problem right-
hand sides / starts (defaults: all-ones / all-zeros, the paper's §7.1
protocol).  ``tol`` may be a scalar or a per-problem sequence.  Each
returned :class:`~repro.core.cg.CGResult` matches what the single-system
:func:`~repro.core.cg.jpcg_solve` would have produced for that lane (to
scheme tolerance; iteration counts agree within ±1).

Bucket policy / compile cache
-----------------------------
Heterogeneous problems are padded to a shared shape before stacking:
every structural dimension (row blocks, slabs, slab length / ELL slots,
col tiles) is rounded UP to a power-of-two bucket edge
(:func:`repro.sparse.stacking.bucket_up`), so traffic whose sizes vary
continuously collapses onto ``O(log n)`` distinct compiled shapes — the
batched restatement of ``cg.py``'s "one compiled program per padded
bucket".  Executables are held in an explicit cache keyed by
``(backend, batch, bucket dims, scheme, maxiter, trace)``;
:func:`batch_cache_info` exposes hit/miss counts so tests (and the
serving engine) can assert reuse.

Running the tests without ``hypothesis``
----------------------------------------
The tier-1 suite imports ``given/settings/strategies`` from
``tests/_hyp.py``, which falls back to deterministic fixed-example
sampling when the real ``hypothesis`` package is absent — so
``PYTHONPATH=src python -m pytest -x -q`` runs green on a bare image;
see ``tests/README.md``.
"""
from __future__ import annotations

from functools import partial
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cg import CGResult
from repro.core.metrics import (advance_status, finalize_status,
                                initial_status, is_breakdown,
                                solver_metrics, status_name, tick_health)
from repro.core.phases import vsr_iteration
from repro.core.precision import PrecisionScheme, get_scheme
from repro.sparse.csr import CSRMatrix, csr_from_coo
from repro.sparse.ellpack import csr_to_ellpack
from repro.sparse.stacking import (StackedEllpack, choose_layout,
                                   stack_ellpack, stack_rowell, stack_sell)

__all__ = ["BatchedCGState", "jpcg_solve_batched", "batched_matvec_flat",
           "batched_matvec_rowell", "batched_matvec_sell",
           "batched_matvec_ellpack", "tree_sum", "rounded_products",
           "batch_cache_info", "batch_cache_clear"]


class BatchedCGState(NamedTuple):
    """Per-lane CG state, leading axis = batch."""

    k: jax.Array        # global loop counter (int32 scalar)
    it: jax.Array       # int32[G] per-lane iteration counts
    status: jax.Array   # int32[G] exit codes (repro.core.metrics.STATUS_*)
    x: jax.Array        # [G, n] solutions (frozen once a lane converges)
    r: jax.Array        # [G, n] residuals
    p: jax.Array        # [G, n] search directions
    rz: jax.Array       # [G]
    rr: jax.Array       # [G] per-lane ‖r‖² — the termination scalars
    active: jax.Array   # bool[G] live-lane mask
    trace: jax.Array    # [G, maxiter] rr per iteration, or [G, 0]


def _row_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.sum(a * b, axis=-1)


# --------------------------------------------------------------- matvecs
def batched_matvec_flat(gcols, vals, rows, x, *, n_rows: int,
                        padded_cols: int,
                        scheme: PrecisionScheme) -> jax.Array:
    """Batched SpMV over packed nonzero streams (the XLA backend's M1).

    ``gcols/vals/rows`` are the [G, N] stacked streams of
    :func:`repro.sparse.stacking.stack_flat`; ``x`` is [G, n_rows].
    Gathers x per nonzero, multiplies at the scheme's accumulate dtype,
    and segment-sums into rows — value-identical to
    :func:`repro.core.operators.bell_spmv_jnp` lane by lane (same
    products in the same flattened (block, slab, slot) order), but with
    no [B, T, col_tile] x-tile intermediate.

    **Superseded in the hot path** by :func:`batched_matvec_rowell`: the
    per-nonzero ``segment_sum`` scatter costs ~100 ns/element on XLA
    CPU and dominated the whole iteration (the PR-7 "batched loop loses
    to the python loop by 30×" regression was entirely this op).  Kept
    as the stream-layout reference implementation.
    """
    acc = scheme.spmv_acc_dtype
    G = x.shape[0]
    k = min(x.shape[-1], padded_cols)
    x_in = x.astype(scheme.spmv_in_dtype)
    x_pad = jnp.zeros((G, padded_cols), x_in.dtype).at[:, :k].set(x_in[:, :k])
    xg = jnp.take_along_axis(x_pad, gcols, axis=1)
    prod = vals.astype(acc) * xg.astype(acc)
    seg = partial(jax.ops.segment_sum, num_segments=n_rows)
    y = jax.vmap(seg)(prod, rows)
    return y.astype(scheme.vector_dtype)


def tree_sum(p, axis: int):
    """Deterministic halving-tree reduction over ``axis``.

    ``jnp.sum``'s reduce tree depends on the axis *length* on XLA CPU,
    so trimming trailing zero slots changes result bits — exactly what
    sliced-ELL does to row-ELL's width.  This fold fixes the bracketing:
    pad to a power of two with exact zeros, then repeatedly add the top
    half onto the bottom half.  The bracketing is *suffix-stable* —
    ``T(2w) = T(w)(lo) + T(w)(hi)`` and an all-zero hi folds away
    exactly — so a row reduced at any padded width ≥ its nonzero count
    yields identical bits.  Row-ELL (global W), sliced-ELL (per-slice
    w ≤ W) and the numpy reference all reduce through this one function,
    which is what makes the layouts bit-interchangeable.  Works on
    numpy and jax arrays alike (slicing + ``+`` only).

    Callers that feed *products* into this tree must route them through
    :func:`rounded_products` — XLA:CPU otherwise contracts a bare
    multiply feeding the first fold into an FMA, and *which* shapes get
    contracted is a codegen detail (1-ulp layout-dependent drift,
    exactly what this function exists to prevent).
    """
    ndim = p.ndim
    axis = axis % ndim
    w = p.shape[axis]
    wp = 1 << max(w - 1, 0).bit_length()   # next pow2 (wp >= max(w, 1))
    if wp != w:
        xp = np if isinstance(p, np.ndarray) else jnp
        pad = [(0, 0)] * ndim
        pad[axis] = (0, wp - w)
        p = xp.pad(p, pad)
    w = wp
    ix = [slice(None)] * ndim
    while w > 1:
        h = w // 2
        lo, hi = list(ix), list(ix)
        lo[axis] = slice(0, h)
        hi[axis] = slice(h, w)
        p = p[tuple(lo)] + p[tuple(hi)]
        w = h
    ix[axis] = 0
    return p[tuple(ix)]


def rounded_products(vals, xg, acc):
    """``vals ⊙ xg`` at ``acc`` dtype, pinned to correctly-rounded bits.

    A bare ``v * x`` feeding an add is fair game for LLVM FMA
    contraction on XLA:CPU — the add absorbs the *infinitely precise*
    product, and whether that happens depends on the fused kernel's
    shape.  Row-ELL (width W) and sliced-ELL (width w ≤ W) compile to
    different shapes, so contraction showed up as a 1-ulp cross-layout
    drift (``lax.optimization_barrier`` and XLA fast-math flags do not
    stop it — it happens at LLVM codegen).  Adding a runtime ±0
    (``xg * 0``; opaque to the simplifier since x is a traced value)
    fixes it structurally: the only contractible multiply is consumed
    *here*, into an add whose other operand is zero — and
    ``fma(v, x, ±0) ≡ round(v·x)`` — so what reaches the
    :func:`tree_sum` folds is an add/fma result, never a bare multiply.
    Bit-exact whether or not the compiler contracts.
    """
    v = vals.astype(acc)
    g = xg.astype(acc)
    return v * g + g * jnp.zeros((), acc)


def batched_matvec_rowell(cols, vals, x, *,
                          scheme: PrecisionScheme) -> jax.Array:
    """Batched SpMV over row-major ELL lanes (the XLA backend's M1).

    ``cols/vals`` are the slot-major ``[G, W, n_pad]`` stacked arrays of
    :func:`repro.sparse.stacking.stack_rowell`; ``x`` is ``[G, n_pad]``.
    ``y[g, i] = Σ_w vals[g, w, i] · x[g, cols[g, w, i]]`` — a gather
    plus a :func:`tree_sum` over the width axis (each tree add is
    contiguous over the row lanes; the deterministic bracketing is what
    keeps row-ELL and sliced-ELL bit-identical).  No scatter anywhere:
    this is why one batched iteration costs arithmetic instead of
    ~100 ns/nonzero of XLA-CPU ``segment_sum`` (see
    :func:`batched_matvec_flat`).  Casts follow the scheme contract
    (matrix dtype on ``vals`` packed at rest by the stacker, ``spmv_in``
    on the gathered x, accumulate at ``spmv_acc``, result at
    ``vector``).
    """
    acc = scheme.spmv_acc_dtype
    x_in = x.astype(scheme.spmv_in_dtype)
    xg = jax.vmap(lambda xv, c: xv[c])(x_in, cols)        # [G, W, n_pad]
    y = tree_sum(rounded_products(vals, xg, acc), axis=1)
    return y.astype(scheme.vector_dtype)


def batched_matvec_sell(cols, vals, iperm, x, *, groups,
                        scheme: PrecisionScheme) -> jax.Array:
    """Batched SpMV over stacked SELL-C-σ lanes (the skewed-matrix M1).

    ``cols/vals`` are the flat slot-major ``[G, L]`` arrays of
    :func:`repro.sparse.stacking.stack_sell`, ``iperm`` the ``[G,
    n_pad]`` un-permutation, ``groups`` the static ``(rows, width)``
    runs.  Each width group is a small row-ELL rectangle: gather +
    :func:`tree_sum` over its own width.  Because the per-row slot order
    matches row-ELL and the tree bracketing is suffix-stable, the result
    is bit-identical to :func:`batched_matvec_rowell` on the same
    matrix — the layout choice is invisible to the solver trajectory.
    """
    acc = scheme.spmv_acc_dtype
    x_in = x.astype(scheme.spmv_in_dtype)
    G = x.shape[0]
    parts, off = [], 0
    for rows, w in groups:
        if w == 0:
            parts.append(jnp.zeros((G, rows), acc))
            continue
        c = cols[:, off:off + rows * w].reshape(G, w, rows)
        v = vals[:, off:off + rows * w].reshape(G, w, rows)
        xg = jax.vmap(lambda xv, cc: xv[cc])(x_in, c)     # [G, w, rows]
        parts.append(tree_sum(rounded_products(v, xg, acc), axis=1))
        off += rows * w
    y_sorted = jnp.concatenate(parts, axis=1)             # [G, n_pad]
    y = jnp.take_along_axis(y_sorted, iperm, axis=1)
    return y.astype(scheme.vector_dtype)


def batched_matvec_ellpack(tile_cols, vals, local_cols, x, *,
                           col_tile: int, n_col_tiles: int,
                           scheme: PrecisionScheme,
                           interpret: bool) -> jax.Array:
    """Batched Pallas SpMV (one kernel launch for all G systems)."""
    from repro.kernels.spmv import spmv_pallas_batched
    G = x.shape[0]
    padded_cols = n_col_tiles * col_tile
    k = min(x.shape[-1], padded_cols)
    x_pad = jnp.zeros((G, padded_cols), x.dtype).at[:, :k].set(x[:, :k])
    x_tiles = x_pad.reshape(G, n_col_tiles, col_tile)
    y = spmv_pallas_batched(tile_cols, vals, local_cols, x_tiles,
                            scheme=scheme, interpret=interpret)
    return y.reshape(G, -1)[:, : x.shape[-1]].astype(scheme.vector_dtype)


# ------------------------------------------------------- loop construction
def _batched_init(matvec, diag, b, x0, *, maxiter, scheme, with_trace,
                  tol, detect=True):
    vd = scheme.vector_dtype
    G = b.shape[0]
    r = b - matvec(x0)
    z = r / diag
    p = z
    rz = _row_dot(r, z)
    rr = _row_dot(r, r)
    trace = jnp.zeros((G, maxiter if with_trace else 0), dtype=vd)
    return BatchedCGState(
        k=jnp.zeros((), jnp.int32), it=jnp.zeros(G, jnp.int32),
        status=initial_status(rr, tol, detect=detect),
        x=x0, r=r, p=p, rz=rz, rr=rr, active=rr > tol, trace=trace)


def _batched_body(matvec, diag, tol, maxiter_vec=None, *, bound=None,
                  write_trace=True, detect=True):
    """Masked VSR iteration over all lanes.

    Frozen (converged) lanes still flow through the arithmetic — that is
    free on a SIMD device — but every state write is gated on ``active``,
    so their ``x`` stops updating the iteration they converge.  Division
    garbage a frozen lane may produce (0/0 in alpha/beta) is discarded by
    the same gates: ``where`` selects, it never blends.

    ``bound`` makes the tick *self-gating* so it can run inside an
    iteration chunk (:func:`_run_chunked`): the tick is a no-op — no
    state write, no ``k``/``it`` advance — once every lane converged or
    ``k`` reached ``bound``, which is exactly the predicate the
    ``while_loop`` ``cond`` checks.  Evaluating it per tick instead of
    per chunk is what keeps chunked execution bit-identical to k=1 in
    *every* observable, including iteration counts.  ``write_trace=False``
    suppresses the per-tick trace scatter (the chunked runner hoists it
    to one blend per chunk).

    ``detect`` arms in-loop breakdown detection
    (:func:`repro.core.metrics.tick_health` on the tick's own
    ``pAp``/``α``/``β``/``rr`` — no extra arithmetic): a lane that trips
    it freezes *this* tick — writes discarded, ``it`` not advanced,
    ``status`` latched to the breakdown code, lane deactivated.  Healthy
    lanes see the identical dataflow with or without detection (the
    commit mask degenerates to ``keep``), which ``tests/test_health.py``
    locks bit-for-bit.
    """

    def body(s: BatchedCGState) -> BatchedCGState:
        x_new, r_new, p_new, rz_new, rr_new, (pap, alpha, beta) = \
            vsr_iteration(matvec, diag, s.x, s.r, s.p, s.rz, dot=_row_dot,
                          with_aux=True)
        go = jnp.any(s.active)
        if bound is not None:
            go = go & (s.k < bound)
        keep = s.active & go
        upd, bd_i, bd_n = tick_health(keep, pap, alpha, beta, rr_new,
                                      detect=detect)
        kv = upd[:, None]
        x = jnp.where(kv, x_new, s.x)
        r = jnp.where(kv, r_new, s.r)
        p = jnp.where(kv, p_new, s.p)
        rz = jnp.where(upd, rz_new, s.rz)
        rr = jnp.where(upd, rr_new, s.rr)
        it = s.it + upd.astype(jnp.int32)
        if write_trace and s.trace.shape[1]:
            safe_k = jnp.minimum(s.k, s.trace.shape[1] - 1)
            trace = s.trace.at[:, safe_k].set(
                jnp.where(upd & (s.k < s.trace.shape[1]), rr_new,
                          s.trace[:, safe_k]))
        else:
            trace = s.trace
        live = rr > tol
        if maxiter_vec is not None:
            live = live & (it < maxiter_vec)
        if detect:
            live = live & ~(bd_i | bd_n)
        status = advance_status(s.status, upd=upd, bd_indef=bd_i,
                                bd_nonf=bd_n, rr_new=rr_new, tol=tol,
                                it=it, maxiter_vec=maxiter_vec)
        # a no-op tick (go=False) must not re-evaluate liveness
        active = jnp.where(keep, live, s.active)
        return BatchedCGState(k=s.k + go.astype(jnp.int32), it=it,
                              status=status, x=x, r=r, p=p, rz=rz, rr=rr,
                              active=active, trace=trace)

    return body


# -------------------------------------------------------- chunked execution
def _run_chunked(cond, tick, st, *, steps: int, with_trace: bool,
                 maxiter: int, rr_of):
    """Drive ``tick`` to completion, ``steps`` ticks per ``while_loop``
    body (the iteration-chunking knob, ISSUE 7).

    The termination predicate — a host-visible sync on XLA CPU — is
    evaluated once per *chunk*; each tick inside the chunk self-gates
    (see ``bound=`` on the tick builders), so results stay bit-identical
    to ``steps=1`` in every observable: a lane freezes the tick it
    converges, ``k``/``it`` never overshoot, and trailing in-chunk ticks
    after global convergence are discarded no-ops.

    With ``with_trace`` the per-tick trace scatter is *hoisted*: ticks
    run with ``write_trace=False`` while the chunk accumulates the
    ``steps × G`` post-tick ``rr`` values (via ``rr_of``) and advance
    flags, then blends them into the trace with one dynamic slice per
    chunk.  Because every non-final chunk advances ``k`` by exactly
    ``steps``, each chunk starts at a multiple of ``steps`` — the trace
    is padded up to a whole number of chunks and cropped on exit.
    """
    if steps <= 1:
        return jax.lax.while_loop(cond, tick, st)
    if not with_trace:
        def body(s):
            return jax.lax.fori_loop(0, steps, lambda _, ss: tick(ss), s)
        return jax.lax.while_loop(cond, body, st)

    G, width = st.trace.shape
    n_chunks = -(-maxiter // steps)
    padded = n_chunks * steps
    st = st._replace(trace=jnp.pad(st.trace, ((0, 0), (0, padded - width))))

    def body(s):
        zero = jnp.zeros((), s.k.dtype)
        k0 = s.k

        def inner(i, carry):
            ss, rrb, adv = carry
            s2 = tick(ss)
            rrb = rrb.at[i].set(rr_of(s2))
            adv = adv.at[i].set(s2.it != ss.it)   # == this tick's keep mask
            return s2, rrb, adv

        rrb0 = jnp.zeros((steps, G), s.trace.dtype)
        adv0 = jnp.zeros((steps, G), bool)
        s, rrb, adv = jax.lax.fori_loop(0, steps, inner, (s, rrb0, adv0))
        old = jax.lax.dynamic_slice(s.trace, (zero, k0), (G, steps))
        blk = jnp.where(adv.T, rrb.T, old)
        return s._replace(
            trace=jax.lax.dynamic_update_slice(s.trace, blk, (zero, k0)))

    out = jax.lax.while_loop(cond, body, st)
    return out._replace(trace=out.trace[:, :width])


# ------------------------------------------------------------ compile cache
_CACHE: dict = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def batch_cache_info() -> dict:
    """Executable-cache statistics: {entries, hits, misses}."""
    return {"entries": len(_CACHE), **_CACHE_STATS}


def batch_cache_clear() -> None:
    _CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0)


def _cached(key, make):
    fn = _CACHE.get(key)
    if fn is None:
        _CACHE_STATS["misses"] += 1
        fn = _CACHE[key] = make()
    else:
        _CACHE_STATS["hits"] += 1
    return fn


def _matvec_factory(*, backend, scheme, layout=None, groups=None,
                    block_rows=None, col_tile=None, n_col_tiles=None,
                    interpret=False):
    """``matvec_of(mat) -> matvec`` closure for one backend + bucket shape.

    Shared by the solve-to-completion runner and the serving stepper so
    both paths are guaranteed to compute the same M1.  ``layout`` picks
    the matrix operand format: ``"rowell"`` (XLA default, ``mat = (cols,
    vals)`` slot-major ``[G, W, n_pad]``), ``"sell"`` (either backend,
    ``mat = (cols, vals, iperm)`` with static ``groups``), or
    ``"ellpack"`` (Pallas default, the tiled 3-tuple).  The operands
    carry their own shapes — the kernel-tiling parameters only matter
    for the Pallas ellpack path.
    """
    layout = layout or ("rowell" if backend == "xla" else "ellpack")
    if layout == "sell":
        if groups is None:
            raise ValueError("layout='sell' needs the static groups= "
                             "signature of the stacked operand")
        if backend == "xla":
            def matvec_of(mat):
                cols, vals, iperm = mat
                return lambda x: batched_matvec_sell(
                    cols, vals, iperm, x, groups=groups, scheme=scheme)
        elif backend == "pallas":
            def matvec_of(mat):
                from repro.kernels.spmv import spmv_pallas_sell
                cols, vals, iperm = mat
                return lambda x: jnp.take_along_axis(
                    spmv_pallas_sell(cols, vals, x, groups=groups,
                                     scheme=scheme, interpret=interpret),
                    iperm, axis=1).astype(scheme.vector_dtype)
        else:
            raise ValueError(f"unknown backend {backend!r}")
    elif backend == "xla" and layout == "rowell":
        def matvec_of(mat):
            cols, vals = mat
            return lambda x: batched_matvec_rowell(cols, vals, x,
                                                   scheme=scheme)
    elif backend == "pallas" and layout == "ellpack":
        def matvec_of(mat):
            tc, v, lc = mat
            return lambda x: batched_matvec_ellpack(
                tc, v, lc, x, col_tile=col_tile, n_col_tiles=n_col_tiles,
                scheme=scheme, interpret=interpret)
    else:
        raise ValueError(f"unsupported backend/layout combination "
                         f"{backend!r}/{layout!r}")
    return matvec_of


def _make_runner(*, backend, scheme, maxiter, with_trace, layout=None,
                 groups=None, block_rows=None, col_tile=None,
                 n_col_tiles=None, steps_per_sync=8, donate=False,
                 detect=True, interpret=False, mesh=None):
    """Build the jitted solve-to-completion runner for one bucket shape.

    ``steps_per_sync`` = iterations per termination-predicate sync (the
    chunking knob; bit-identical for any value).  ``donate`` marks the
    ``b``/``x0`` operands donated (off by default — see
    :func:`jpcg_solve_batched`).  ``detect`` arms breakdown detection
    (see :func:`_batched_body`); either way leftover ``RUNNING`` statuses
    are finalized to ``MAXITER`` before the state is returned — a solve
    runner's loop only exits with everything terminal or the budget
    spent.  ``mesh`` shards the operands' lane axis over a device mesh
    before the jitted call (:mod:`repro.core.shard`); lanes are
    independent, so the sharded runner is bit-identical to the
    single-device one.
    """
    matvec_of = _matvec_factory(
        backend=backend, scheme=scheme, layout=layout, groups=groups,
        block_rows=block_rows, col_tile=col_tile,
        n_col_tiles=n_col_tiles, interpret=interpret)
    hoist_trace = with_trace and steps_per_sync > 1

    def run(mat, diag, b, x0, tol):
        matvec = matvec_of(mat)
        st = _batched_init(matvec, diag, b, x0, maxiter=maxiter,
                           scheme=scheme, with_trace=with_trace, tol=tol,
                           detect=detect)
        tick = _batched_body(matvec, diag, tol, bound=maxiter,
                             write_trace=not hoist_trace, detect=detect)

        def cond(s):
            return (s.k < maxiter) & jnp.any(s.active)

        out = _run_chunked(cond, tick, st, steps=steps_per_sync,
                           with_trace=with_trace, maxiter=maxiter,
                           rr_of=lambda s: s.rr)
        return out._replace(status=finalize_status(out.status))

    fn = jax.jit(run, donate_argnums=(2, 3) if donate else ())
    if mesh is None:
        return fn
    from repro.core.shard import place_lanes

    def run_sharded(mat, diag, b, x0, tol):
        return fn(place_lanes(mesh, mat), place_lanes(mesh, diag),
                  place_lanes(mesh, b), place_lanes(mesh, x0),
                  place_lanes(mesh, tol))

    return run_sharded


# ---------------------------------------------------------------- public
def _as_csr(a) -> CSRMatrix:
    if isinstance(a, CSRMatrix):
        return a
    arr = np.asarray(a)
    if arr.ndim == 2 and arr.shape[0] == arr.shape[1]:
        rows, cols = np.nonzero(arr)
        return csr_from_coo(rows, cols, arr[rows, cols], arr.shape)
    raise TypeError(f"cannot batch-solve a {type(a)}")


def _pad_stack(vecs: Sequence[np.ndarray], n_pad: int, fill: float,
               dtype) -> jnp.ndarray:
    out = np.full((len(vecs), n_pad), fill, dtype=np.float64)
    for g, v in enumerate(vecs):
        out[g, : v.shape[0]] = np.asarray(v, dtype=np.float64)
    return jnp.asarray(out, dtype=dtype)


def jpcg_solve_batched(problems: Sequence, bs: Optional[Sequence] = None,
                       x0s: Optional[Sequence] = None, *,
                       tol=1e-12, maxiter: int = 20_000,
                       scheme="mixed_v3", backend: str = "xla",
                       engine: str = "vm", policy: Optional[str] = None,
                       program: Optional[np.ndarray] = None,
                       specialize: bool = True,
                       block_rows: int = 256, col_tile: int = 512,
                       bucket: bool = True, layout: str = "auto",
                       with_trace: bool = False,
                       steps_per_sync: int = 8, donate: bool = False,
                       detect: bool = True, with_status: bool = True,
                       interpret: Optional[bool] = None,
                       mesh=None) -> List[CGResult]:
    """Solve B independent SPD systems in one compiled ``lax.while_loop``.

    See the module docstring for the batch API, bucket policy, and the
    ``engine``/``policy``/``program`` knobs (default: the batched stream
    VM running the compiled paper-policy program; ``policy``/``program``
    only make sense with ``engine="vm"`` and are rejected otherwise —
    the phases engine hard-codes its schedule).  ``specialize`` (default
    True) unrolls the program into the executable at trace time — the
    fast straight-line path, cached per program bytes;
    ``specialize=False`` keeps the program a traced operand so one
    executable serves every program of the same padded length.  Lanes
    terminate on the fly at their own ``‖r‖² ≤ tol_g``; the compiled
    loop runs until every lane converged or ``maxiter``.

    ``steps_per_sync`` (static, joins the executable cache key) is the
    iteration-chunking knob: the loop syncs its termination predicate
    with the host once per that many iterations.  Any value produces
    bit-identical results — each in-chunk tick self-gates (see
    :func:`_batched_body`) — so the default 8 trades nothing but
    predicate-sync latency.  ``donate`` marks the fresh ``b``/``x0``
    operands donated; it's off by default because a solve-to-completion
    call consumes them *inside* the computation (XLA's own liveness
    already reuses the buffers) and would only warn that no output can
    alias them — donation earns its keep on the serving steppers, whose
    state argument round-trips through the jit boundary every tick.

    ``layout`` picks the stacked matrix format: ``"auto"`` (default)
    applies the padding-ratio heuristic
    (:func:`repro.sparse.stacking.choose_layout` — sliced-ELL when
    ``Σ n·W / Σ nnz`` exceeds
    :data:`~repro.sparse.stacking.SELL_PADDING_THRESHOLD`, else the
    backend default), ``"rowell"`` / ``"sell"`` force it on the XLA
    backend, ``"ellpack"`` / ``"sell"`` on Pallas.  Values are packed at
    ``scheme.matrix_dtype`` and indices at int16/int32 by ``n_pad`` at
    stacking time; the layout and index width join the executable cache
    key.  Every layout is bit-identical to every other for the same
    scheme (shared :func:`tree_sum` reduction bracketing).

    ``detect`` (default True; static, joins the cache key) arms in-loop
    breakdown detection: a lane whose tick produces ``pAp ≤ 0`` or a
    non-finite ``rr``/``α``/``β`` freezes immediately with a breakdown
    status instead of spinning to ``maxiter`` — bit-invisible to healthy
    lanes (see :mod:`repro.core.metrics`).  ``with_status`` (default
    True) reports each lane's exit as ``CGResult.status``
    (``"CONVERGED"`` / ``"MAXITER"`` / ``"BREAKDOWN_INDEFINITE"`` /
    ``"BREAKDOWN_NONFINITE"``); ``with_status=False`` restores the
    legacy ``status=None`` result for callers that compare results
    structurally.  Each call also feeds the process-wide
    :func:`repro.core.metrics.solver_metrics` counters (iterations,
    SpMV-call and streamed-byte estimates, exit histogram).

    ``mesh`` (a 1-D :class:`jax.sharding.Mesh`, e.g.
    :func:`repro.core.shard.lane_mesh`) shards the *lane* axis over D
    devices: operands are laid out with ``NamedSharding`` over the
    ``lanes`` axis and the batch is padded up to a multiple of D with
    inert identity lanes (converged at admission, dropped from the
    results).  Lanes are independent, so the sharded solve is
    **bit-identical** to ``mesh=None`` for every scheme × layout ×
    engine (locked by ``tests/test_shard.py``); the mesh signature
    joins the executable cache key, so single-device and sharded
    executables never collide.
    """
    if engine != "vm" and (policy is not None or program is not None):
        raise ValueError(
            f"policy=/program= select the stream-VM's program; they have "
            f"no effect under engine={engine!r} — drop them or use "
            "engine='vm'")
    if policy is not None and program is not None:
        raise ValueError("pass either policy= (compiled for you) or "
                         "program= (pre-assembled), not both")
    scheme = get_scheme(scheme)
    if (scheme.vector_dtype == jnp.float64
            and not jax.config.read("jax_enable_x64")):
        raise RuntimeError(
            f"scheme {scheme.name!r} needs fp64 vectors: enable x64 first "
            "or use a TPU-tier scheme (tpu_v3, ...).")
    csrs = [_as_csr(a) for a in problems]
    G = len(csrs)
    if G == 0:
        return []
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()

    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    if layout in (None, "auto"):
        layout = choose_layout(
            csrs, default="rowell" if backend == "xla" else "ellpack")
    # Lane sharding: NamedSharding needs the lane axis divisible by the
    # shard count, so the bag is padded with inert identity lanes
    # (b = x0 = 0 -> rr = 0, converged at admission, dropped from the
    # results).  Padding happens after the layout heuristic so the
    # choice is driven by the real problems only.
    G_real = G
    if mesh is not None:
        from repro.core.shard import pad_lanes
        G = pad_lanes(G, mesh)
        if G != G_real:
            csrs = csrs + [_as_csr(np.eye(1))] * (G - G_real)
    groups = None
    n_col_tiles = None
    if layout == "sell":
        stacked = stack_sell(csrs, bucket=bucket, scheme=scheme)
        mat = (jnp.asarray(stacked.cols), jnp.asarray(stacked.vals),
               jnp.asarray(stacked.iperm))
        groups = stacked.groups
        # flat ints only: executable_key ravels the bucket dims
        bucket_dims = (stacked.padded_rows,
                       *(d for rw in groups for d in rw))
        index_bytes = stacked.index_bytes
    elif backend == "xla" and layout == "rowell":
        stacked = stack_rowell(csrs, bucket=bucket, scheme=scheme)
        mat = (jnp.asarray(stacked.cols), jnp.asarray(stacked.vals))
        bucket_dims = (stacked.padded_rows, stacked.width)
        index_bytes = stacked.index_bytes
    elif backend == "pallas" and layout == "ellpack":
        stacked_e: StackedEllpack = stack_ellpack(
            [csr_to_ellpack(a, block_rows=block_rows, col_tile=col_tile)
             for a in csrs], bucket=bucket)
        mat = (jnp.asarray(stacked_e.tile_cols),
               jnp.asarray(stacked_e.vals).astype(scheme.matrix_dtype),
               jnp.asarray(stacked_e.local_cols))
        stacked = stacked_e
        n_col_tiles = stacked_e.n_col_tiles
        bucket_dims = stacked_e.vals.shape[1:]
        index_bytes = int(stacked_e.local_cols.dtype.itemsize)
    else:
        raise ValueError(f"unsupported backend/layout combination "
                         f"{backend!r}/{layout!r}")

    vd = scheme.vector_dtype
    n_pad = stacked.padded_rows
    ns = [s[0] for s in stacked.shapes]
    # Padded rows get a unit diagonal and zero rhs: their residual is
    # identically zero, so they never influence rr or termination.
    diag = _pad_stack([a.diagonal() for a in csrs], n_pad, 1.0, vd)
    bs = list(bs) if bs is not None else [np.ones(n) for n in ns[:G_real]]
    x0s = (list(x0s) if x0s is not None
           else [np.zeros(n) for n in ns[:G_real]])
    for name, seq in (("bs", bs), ("x0s", x0s)):
        if len(seq) != G_real:
            raise ValueError(
                f"{name} has {len(seq)} entries for {G_real} problems")
        for g, v in enumerate(seq):
            if np.shape(v) != (ns[g],):
                raise ValueError(
                    f"{name}[{g}] has shape {np.shape(v)}, expected "
                    f"({ns[g]},) for problem {g}")
    if G != G_real:
        # Shard-padding lanes: zero rhs/start on the identity dummy.
        bs = bs + [np.zeros(1)] * (G - G_real)
        x0s = x0s + [np.zeros(1)] * (G - G_real)
    b = _pad_stack(bs, n_pad, 0.0, vd)
    x0 = _pad_stack(x0s, n_pad, 0.0, vd)
    if np.ndim(tol) == 0:
        tol_vec = jnp.full(G, float(tol), vd)
    else:
        if len(tol) != G_real:
            raise ValueError(
                f"tol has {len(tol)} entries for {G_real} problems")
        tol_vec = jnp.asarray(
            np.concatenate([np.asarray(tol, np.float64),
                            np.ones(G - G_real)]), vd)

    if engine == "vm":
        # Specialized (default): the program is unrolled into the
        # executable, so its bytes join the cache key (via program_token)
        # — word-identical programs share one executable.  Generic
        # fallback: the executable is keyed on the bucket — NOT on the
        # program or policy; the program is a runtime operand (program
        # *length* participates only through the operand's shape).
        from repro.core.compile import canonical_program, executable_key
        from repro.core.isa import BUF, SREG
        from repro.core.vm import make_vm_runner
        if program is None:
            policy = "paper" if policy is None else policy
            program = canonical_program(policy)
            method = f"vm_batched[{policy}]"
        else:
            method = "vm_batched[custom]"
        if not specialize:
            method += "|generic"
        prog_np = np.asarray(program, np.int32)
        runner_kw = dict(
            backend=backend, scheme=scheme, maxiter=maxiter,
            with_trace=with_trace, layout=layout, groups=groups,
            block_rows=block_rows, col_tile=col_tile,
            n_col_tiles=n_col_tiles, steps_per_sync=steps_per_sync,
            donate=donate, detect=detect, interpret=interpret, mesh=mesh)
        key_kw = dict(
            backend=backend, scheme=scheme.name, batch=G,
            bucket=bucket_dims, layout=layout, index_bytes=index_bytes,
            maxiter=maxiter, with_trace=with_trace,
            steps_per_sync=steps_per_sync, donate=donate, detect=detect,
            interpret=interpret, mesh=mesh)
        if specialize:
            key = executable_key("vm_solve_spec", program=prog_np,
                                 **key_kw)
            run = _cached(key, lambda: make_vm_runner(program=prog_np,
                                                      **runner_kw))
            st = run(mat, diag, b, x0, tol_vec)
        else:
            key = executable_key("vm_solve", **key_kw)
            run = _cached(key, lambda: make_vm_runner(**runner_kw))
            st = run(jnp.asarray(prog_np), mat, diag, b, x0, tol_vec)
        xs = st.mem[BUF["x"]]
        rrs_dev, trace_dev = st.sregs[SREG["rr"]], st.trace
    elif engine == "phases":
        from repro.core.compile import executable_key
        key = executable_key(
            "solve", backend=backend, scheme=scheme.name, batch=G,
            bucket=bucket_dims, layout=layout, index_bytes=index_bytes,
            maxiter=maxiter, with_trace=with_trace,
            steps_per_sync=steps_per_sync, donate=donate, detect=detect,
            interpret=interpret, mesh=mesh)
        run = _cached(key, lambda: _make_runner(
            backend=backend, scheme=scheme, maxiter=maxiter,
            with_trace=with_trace, layout=layout, groups=groups,
            block_rows=block_rows, col_tile=col_tile,
            n_col_tiles=n_col_tiles, steps_per_sync=steps_per_sync,
            donate=donate, detect=detect, interpret=interpret, mesh=mesh))
        st = run(mat, diag, b, x0, tol_vec)
        xs, rrs_dev, trace_dev = st.x, st.rr, st.trace
        method = "vsr_batched"
    else:
        raise ValueError(f"unknown engine {engine!r}")

    its = np.asarray(st.it)
    rrs = np.asarray(rrs_dev)
    tols = np.asarray(tol_vec)
    statuses = np.asarray(st.status)

    # Observability (estimates, host-side): one SpMV per warm-up, per
    # committed iteration, and per discarded breakdown tick; streamed
    # bytes = events x the lane's at-rest nonzero stream (values +
    # indices as packed — padding already included, so this IS
    # nonzero_stream_bytes x padding_ratio x nnz).
    m = solver_metrics()
    if layout == "ellpack":
        lane_stream_bytes = (mat[1].nbytes + mat[2].nbytes) // G
    else:
        lane_stream_bytes = (mat[0].nbytes + mat[1].nbytes) // G
    # A breakdown lane spent one discarded tick iff it actually entered
    # the loop: an in-loop breakdown freezes at its pre-tick rr (always
    # finite), while a lane latched non-finite at admission keeps its
    # non-finite warm-up rr and never ticked.  Shard-padding lanes
    # (g >= G_real) are inert and invisible to the accounting.
    n_bd = int(sum(is_breakdown(int(c)) and np.isfinite(rrs[g])
                   for g, c in enumerate(statuses[:G_real])))
    spmv_events = G_real + int(its[:G_real].sum()) + n_bd
    m.bump("solves")
    m.bump("lanes", G_real)
    m.bump("iterations", int(its[:G_real].sum()))
    m.bump("spmv_calls", spmv_events)
    m.bump("bytes_streamed_est", spmv_events * int(lane_stream_bytes))
    m.record_exits(statuses[:G_real])

    results = []
    for g in range(G_real):
        trace = (np.asarray(trace_dev[g])[: its[g]] if with_trace else None)
        results.append(CGResult(
            x=xs[g, : ns[g]], iterations=int(its[g]), rr=float(rrs[g]),
            converged=bool(rrs[g] <= tols[g]), residual_trace=trace,
            scheme=scheme.name, method=method,
            status=status_name(int(statuses[g])) if with_status else None))
    return results
