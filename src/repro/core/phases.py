"""Phase-structured JPCG loop — the production solver (paper Alg. 1 + §5).

The loop body is written exactly along the VSR phase partition computed by
:mod:`repro.core.vsr`:

* **Phase 1**: M1 SpMV (``ap = A·p``) then M2 dot (``pap = p·ap``) —
  barrier: ``alpha = rz / pap``.
* **Phase 2**: fused ``r' = r − α·ap`` (M4), ``rr = r'·r'`` (M8, hoisted
  before M5 like the paper's controller so termination is known as early
  as possible), ``z = M⁻¹·r'`` (M5), ``rz' = r'·z`` (M6) — barrier:
  ``beta = rz'/rz``.
* **Phase 3**: ``p' = z + β·p`` (M7), ``x' = x + α·p`` (M3).

``z`` is never materialized to HBM (paper §5.3): inside one jit region XLA
fuses the phase-2/3 elementwise chains so ``z`` lives only in registers/
VMEM; the Pallas backend (:mod:`repro.kernels.fused_phase`) makes the same
guarantee explicitly.  Note a pleasing collapse: the paper's "recompute M4+
M5 in phase 3" and our min-traffic "store r' in phase 2" schedules produce
*identical jitted HLO* here, because XLA CSEs the recompute — the policy
distinction is observable only at the VM/kernel level (see DESIGN.md).

Termination is on-the-fly (paper Challenge 1): a ``lax.while_loop`` whose
predicate reads the scalar ``rr`` produced *inside* the loop body — one
compiled program serves any matrix and any iteration count.

Since the batched stream VM became the default solver backend
(:mod:`repro.core.vm`), this phase-fused loop is the VM's *oracle*: the
batched engine keeps an ``engine="phases"`` path built from
:func:`vsr_iteration`, and ``tests/test_compile.py`` asserts the VM's
per-lane results are bit-identical to it.  Keep the two in lockstep —
any arithmetic change here must reproduce in the compiled programs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionScheme

__all__ = ["CGState", "jpcg_loop", "init_state", "vsr_iteration"]


class CGState(NamedTuple):
    i: jax.Array          # iteration counter (int32)
    x: jax.Array          # current solution
    r: jax.Array          # residual
    p: jax.Array          # search direction
    rz: jax.Array         # (r, z)
    rr: jax.Array         # ‖r‖² — the termination scalar
    trace: jax.Array      # rr per iteration ((maxiter,) or (0,))


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b)


def vsr_iteration(matvec, diag, x, r, p, rz, *, dot=_dot, with_aux=False):
    """One VSR-scheduled JPCG iteration (phases 1–3) on raw vectors.

    Shared by the single-system loop below and the batched engine
    (:mod:`repro.core.batch`), which passes a row-wise ``dot`` and
    vectors carrying a leading batch axis — the phase dataflow is
    literally the same code, so the two paths cannot drift.

    Returns ``(x', r', p', rz', rr')``; with ``with_aux`` the tick's
    internal scalars ``(pap, alpha, beta)`` ride along as a sixth
    element so breakdown detection (:mod:`repro.core.metrics`) can
    classify the tick without recomputing anything.
    """
    # ---- Phase 1: M1 (SpMV), M2 (dot) -> alpha ----
    ap = matvec(p)
    pap = dot(p, ap)
    alpha = rz / pap
    al = alpha[..., None] if jnp.ndim(alpha) else alpha
    # ---- Phase 2: M4, M8, M5, M6 -> beta ----
    r_new = r - al * ap
    rr_new = dot(r_new, r_new)           # M8 hoisted: early termination
    z = r_new / diag                     # M5 (never stored)
    rz_new = dot(r_new, z)               # M6
    beta = rz_new / rz
    be = beta[..., None] if jnp.ndim(beta) else beta
    # ---- Phase 3: M7, M3 ----
    p_new = z + be * p
    x_new = x + al * p
    if with_aux:
        return x_new, r_new, p_new, rz_new, rr_new, (pap, alpha, beta)
    return x_new, r_new, p_new, rz_new, rr_new


def init_state(matvec, diag, b, x0, *, maxiter: int,
               scheme: PrecisionScheme, with_trace: bool) -> CGState:
    """Paper Alg. 1 lines 1–5 (the controller's rp = −1 warm-up pass)."""
    vd = scheme.vector_dtype
    b = b.astype(vd)
    x0 = x0.astype(vd)
    r = b - matvec(x0)
    z = r / diag
    p = z
    rz = _dot(r, z)
    rr = _dot(r, r)
    trace = jnp.zeros(maxiter if with_trace else 0, dtype=vd)
    return CGState(i=jnp.zeros((), jnp.int32), x=x0, r=r, p=p, rz=rz, rr=rr,
                   trace=trace)


def jpcg_loop(matvec, diag, state: CGState, *, tol: float, maxiter: int,
              scheme: PrecisionScheme, phase_ops=None) -> CGState:
    """Run Alg. 1's main loop until ``rr <= tol`` or ``i == maxiter``.

    ``phase_ops`` — optional ``(dot, phase2, phase3)`` triple (see
    :func:`repro.kernels.ops.make_phase_ops`): when given, each phase runs
    as one fused Pallas kernel instead of the jnp expressions below (which
    XLA fuses to the same dataflow — the jnp path IS the oracle).
    """
    vd = scheme.vector_dtype
    tol = jnp.asarray(tol, dtype=vd)

    def cond(s: CGState) -> jax.Array:
        return (s.i < maxiter) & (s.rr > tol)

    def body_jnp(s: CGState) -> CGState:
        x_new, r_new, p_new, rz_new, rr_new = vsr_iteration(
            matvec, diag, s.x, s.r, s.p, s.rz)
        trace = s.trace.at[s.i].set(rr_new) if s.trace.shape[0] else s.trace
        return CGState(i=s.i + 1, x=x_new, r=r_new, p=p_new, rz=rz_new,
                       rr=rr_new, trace=trace)

    def body_kernels(s: CGState) -> CGState:
        dot, phase2, phase3 = phase_ops
        # ---- Phase 1: SpMV kernel + dot kernel -> alpha ----
        ap = matvec(s.p)
        pap = dot(s.p, ap)
        alpha = s.rz / pap
        # ---- Phase 2: ONE fused kernel (M4+M8+M5+M6) -> beta ----
        r_new, scal = phase2(alpha, s.r, ap, diag)
        rr_new, rz_new = scal[0], scal[1]
        beta = rz_new / s.rz
        # ---- Phase 3: ONE fused kernel (M5-recompute+M7+M3) ----
        p_new, x_new = phase3(alpha, beta, r_new, diag, s.p, s.x)
        trace = s.trace.at[s.i].set(rr_new) if s.trace.shape[0] else s.trace
        return CGState(i=s.i + 1, x=x_new, r=r_new, p=p_new, rz=rz_new,
                       rr=rr_new, trace=trace)

    body = body_jnp if phase_ops is None else body_kernels
    return jax.lax.while_loop(cond, body, state)
