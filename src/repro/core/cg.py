"""Public JPCG solver API.

>>> from repro.core.cg import jpcg_solve
>>> res = jpcg_solve(A, b, scheme="mixed_v3", tol=1e-12, maxiter=20_000)
>>> res.x, res.iterations, res.converged

Matches the paper's evaluation protocol (§7.1): b defaults to all-ones,
x0 to all-zeros, stop criterion ‖r‖² < 1e-12, 20 K max iterations.

``A`` may be a :class:`~repro.sparse.csr.CSRMatrix`, a
:class:`~repro.sparse.bell.BellMatrix`, a dense array, or a matrix-free
callable (with explicit ``diag``/``n``) — the "arbitrary problem" goal of
the paper's Challenge 1: the compiled program is reused across problems of
the same padded bucket, and termination is decided on the fly inside the
``lax.while_loop``.

``method``:
  * ``"vsr"``       — the paper-faithful three-phase loop (default);
  * ``"pipelined"`` — beyond-paper single-reduction variant (see
    :mod:`repro.core.pipelined`).

``backend``:
  * ``"xla"``    — pure-jnp phase ops (runs everywhere; default);
  * ``"pallas"`` — Pallas kernels for SpMV + fused phases (TPU layout;
    ``interpret=True`` on CPU).

For solving MANY independent systems per compiled call (the serving
path), see :func:`repro.core.batch.jpcg_solve_batched` — also reachable
as ``repro.core.cg.jpcg_solve_batched``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import phases as _phases
from repro.core import pipelined as _pipe
from repro.core.operators import as_operator
from repro.core.precision import get_scheme

__all__ = ["CGResult", "jpcg_solve", "jpcg_solve_batched"]


def __getattr__(name):
    # Lazy: batch.py imports CGResult from here, so the batched entry
    # point is resolved on first touch to avoid an import cycle.
    if name == "jpcg_solve_batched":
        from repro.core.batch import jpcg_solve_batched
        return jpcg_solve_batched
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class CGResult:
    x: jax.Array
    iterations: int
    rr: float               # final ‖r‖²
    converged: bool
    residual_trace: Optional[np.ndarray]   # rr per iteration, if requested
    scheme: str
    method: str
    # Exit status name (repro.core.metrics.STATUS_NAMES): "CONVERGED" /
    # "MAXITER" / "BREAKDOWN_INDEFINITE" / "BREAKDOWN_NONFINITE"; None
    # from paths that predate the health layer or with_status=False.
    status: Optional[str] = None
    # True when the serving engine's escalation policy re-ran this
    # request at fp64 after a mixed-precision breakdown.
    retried: bool = False

    def __repr__(self) -> str:  # keep array printing out of logs
        extra = f", status={self.status}" if self.status else ""
        extra += ", retried" if self.retried else ""
        return (f"CGResult(iters={self.iterations}, rr={self.rr:.3e}, "
                f"converged={self.converged}, scheme={self.scheme}, "
                f"method={self.method}{extra})")


@partial(jax.jit, static_argnames=("tol", "maxiter", "scheme", "with_trace",
                                   "backend"))
def _run_vsr(op, diag, b, x0, *, tol, maxiter, scheme, with_trace,
             backend="xla"):
    st = _phases.init_state(op.matvec, diag, b, x0, maxiter=maxiter,
                            scheme=scheme, with_trace=with_trace)
    phase_ops = None
    if backend == "pallas":
        from repro.kernels.ops import make_phase_ops
        phase_ops = make_phase_ops()
    return _phases.jpcg_loop(op.matvec, diag, st, tol=tol, maxiter=maxiter,
                             scheme=scheme, phase_ops=phase_ops)


@partial(jax.jit, static_argnames=("tol", "maxiter", "scheme", "with_trace",
                                   "replace_every"))
def _run_pipe(op, diag, b, x0, *, tol, maxiter, scheme, with_trace,
              replace_every):
    st = _pipe.pipecg_init(op.matvec, diag, b, x0, maxiter=maxiter,
                           scheme=scheme, with_trace=with_trace)
    return _pipe.pipecg_loop(op.matvec, diag, b, st, tol=tol, maxiter=maxiter,
                             scheme=scheme, replace_every=replace_every)


def jpcg_solve(a, b=None, x0=None, *, tol: float = 1e-12,
               maxiter: int = 20_000, scheme="mixed_v3", method: str = "vsr",
               backend: str = "xla", diag=None, n: Optional[int] = None,
               with_trace: bool = False, replace_every: int = 50,
               block_rows: int = 256, col_tile: int = 512) -> CGResult:
    scheme = get_scheme(scheme)
    if scheme.vector_dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
        raise RuntimeError(
            f"scheme {scheme.name!r} needs fp64 vectors: enable x64 via "
            "jax.config.update('jax_enable_x64', True) before creating arrays, "
            "or use a TPU-tier scheme (tpu_v3, ...).")

    if backend == "pallas":
        from repro.kernels.ops import bell_operator_pallas
        op = bell_operator_pallas(a, scheme, diag=diag,
                                  block_rows=block_rows, col_tile=col_tile)
    elif backend == "xla":
        op = as_operator(a, scheme, diag=diag, n=n, block_rows=block_rows,
                         col_tile=col_tile)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    vd = scheme.vector_dtype
    n_ = op.n
    b = (jnp.ones(n_, vd) if b is None else jnp.asarray(b)).astype(vd)
    x0 = (jnp.zeros(n_, vd) if x0 is None else jnp.asarray(x0)).astype(vd)
    d = jnp.asarray(op.diag).astype(vd)

    if method == "vsr":
        st = _run_vsr(op, d, b, x0, tol=tol, maxiter=maxiter,
                      scheme=scheme, with_trace=with_trace, backend=backend)
    elif method == "pipelined":
        st = _run_pipe(op, d, b, x0, tol=tol, maxiter=maxiter,
                       scheme=scheme, with_trace=with_trace,
                       replace_every=replace_every)
    else:
        raise ValueError(f"unknown method {method!r}")

    iters = int(st.i)
    rr = float(st.rr)
    trace = None
    if with_trace:
        trace = np.asarray(st.trace)[:iters]
    return CGResult(x=st.x, iterations=iters, rr=rr,
                    converged=bool(rr <= tol), residual_trace=trace,
                    scheme=scheme.name, method=method)
