"""Pipelined JPCG (Ghysels–Vanroose) — beyond-paper, pod-scale variant.

Callipepla's three-phase schedule has **two** scalar barriers per iteration
(α after the p·ap dot, β after the r·z dot).  On a single FPGA/chip a
barrier costs one extra sweep over HBM; on a 256–512-chip pod each barrier
is a *latency-bound all-reduce over ICI/DCN*, and two sequential reductions
dominate once the per-chip vector slice is small.

The pipelined CG recurrence (Ghysels & Vanroose, 2014) restructures the
iteration so that all three scalars (γ = r·u, δ = w·u, ‖r‖²) are computed
**in one fused reduction**, and the SpMV (n = A·m) is *independent of the
in-flight reduction* — compute/communication overlap that XLA's scheduler
(and the shard_map lowering) exploits directly.

Cost model (recorded in EXPERIMENTS.md §Perf):

* standard VSR JPCG: 14 vector accesses / iter (10R+4W),  2 reductions;
* min-traffic JPCG:  13 vector accesses / iter (9R+4W),   2 reductions;
* pipelined JPCG:    20 vector accesses / iter (11R+9W),  **1** reduction,
  overlapped with the SpMV.

⇒ bandwidth-bound (large N / chip): Callipepla's schedule wins;
  latency-bound (pod scale, small N / chip): pipelined wins.  The solver
  exposes ``method={"vsr","pipelined"}`` and the distributed layer defaults
  to pipelined above a mesh-size threshold.

Numerical note: pipelined CG's recurrences accumulate rounding error faster
than true-residual CG; we follow standard practice with periodic residual
replacement (every ``replace_every`` iterations, recompute r = b − A·x and
the dependent recurrences from scratch), restoring the FP64-equivalent
convergence the paper requires.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionScheme

__all__ = ["PipeCGState", "pipecg_init", "pipecg_loop"]


class PipeCGState(NamedTuple):
    i: jax.Array
    x: jax.Array
    r: jax.Array   # residual
    u: jax.Array   # M⁻¹ r
    w: jax.Array   # A u
    z: jax.Array   # A q-direction accumulator
    q: jax.Array   # M⁻¹ p accumulator
    s: jax.Array   # A p accumulator
    p: jax.Array   # search direction
    gamma: jax.Array       # (r, u)
    gamma_prev: jax.Array
    delta: jax.Array       # (w, u)
    alpha_prev: jax.Array
    rr: jax.Array          # ‖r‖²
    trace: jax.Array


def _dots3(r, u, w):
    """The single fused reduction: γ, δ, ‖r‖² in one pass over r, u, w.

    In the distributed solver this lowers to ONE psum of a length-3 vector
    (vs. two sequential scalar all-reduces for standard CG).
    """
    g = jnp.dot(r, u)
    d = jnp.dot(w, u)
    rr = jnp.dot(r, r)
    return jnp.stack([g, d, rr])


def pipecg_init(matvec, diag, b, x0, *, maxiter: int, scheme: PrecisionScheme,
                with_trace: bool) -> PipeCGState:
    vd = scheme.vector_dtype
    b = b.astype(vd)
    x = x0.astype(vd)
    r = b - matvec(x)
    u = r / diag
    w = matvec(u)
    gdr = _dots3(r, u, w)
    zero = jnp.zeros_like(r)
    one = jnp.ones((), vd)
    trace = jnp.zeros(maxiter if with_trace else 0, dtype=vd)
    return PipeCGState(i=jnp.zeros((), jnp.int32), x=x, r=r, u=u, w=w,
                       z=zero, q=zero, s=zero, p=zero,
                       gamma=gdr[0], gamma_prev=one, delta=gdr[1],
                       alpha_prev=one, rr=gdr[2], trace=trace)


def pipecg_loop(matvec, diag, b, state: PipeCGState, *, tol: float,
                maxiter: int, scheme: PrecisionScheme,
                replace_every: int = 50) -> PipeCGState:
    vd = scheme.vector_dtype
    tol = jnp.asarray(tol, dtype=vd)
    b = b.astype(vd)

    def cond(st: PipeCGState) -> jax.Array:
        return (st.i < maxiter) & (st.rr > tol)

    def body(st: PipeCGState) -> PipeCGState:
        # -- overlap region: this SpMV is independent of the dots of step i --
        m = st.w / diag                      # M⁻¹ w
        n = matvec(m)                        # A m   (overlaps the reduction)
        first = st.i == 0
        beta = jnp.where(first, jnp.zeros((), vd), st.gamma / st.gamma_prev)
        denom = st.delta - beta * st.gamma / jnp.where(
            first, jnp.ones((), vd), st.alpha_prev)
        alpha = st.gamma / jnp.where(first, st.delta, denom)
        # -- fused 8-vector update sweep (one pass over HBM) --
        z = n + beta * st.z
        q = m + beta * st.q
        s = st.w + beta * st.s
        p = st.u + beta * st.p
        x = st.x + alpha * p
        r = st.r - alpha * s
        u = st.u - alpha * q
        w = st.w - alpha * z
        # -- periodic residual replacement for FP64-grade stability --
        def replace(args):
            x_c, *_ = args
            r_t = b - matvec(x_c)
            u_t = r_t / diag
            w_t = matvec(u_t)
            return r_t, u_t, w_t

        def keep(args):
            _, r_c, u_c, w_c = args
            return r_c, u_c, w_c

        do_replace = (replace_every > 0) & (
            st.i % max(replace_every, 1) == max(replace_every, 1) - 1)
        r, u, w = jax.lax.cond(do_replace, replace, keep, (x, r, u, w))
        gdr = _dots3(r, u, w)
        trace = st.trace.at[st.i].set(gdr[2]) if st.trace.shape[0] else st.trace
        return PipeCGState(i=st.i + 1, x=x, r=r, u=u, w=w, z=z, q=q, s=s, p=p,
                           gamma=gdr[0], gamma_prev=st.gamma, delta=gdr[1],
                           alpha_prev=alpha, rr=gdr[2], trace=trace)

    return jax.lax.while_loop(cond, body, state)
