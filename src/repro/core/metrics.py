"""Solver health + observability primitives (ISSUE 9).

The paper's Challenge 1 is terminating acceleration *on the fly*; the
serving-scale completion of that challenge is terminating lanes that can
**never** get to ``‖r‖² ≤ τ_g``.  CG breaks down in two recognizable
ways (classic IC/JPCG folklore):

* **indefinite** — ``pAp ≤ 0``: the operand is not positive definite
  along the current search direction (an indefinite or singular matrix,
  or a matrix whose low-precision packing rounded it singular), so
  ``α = rz/pAp`` stops being a descent step;
* **non-finite** — ``rr``/``α``/``β`` leaves the reals (NaN/Inf seeded
  by the inputs, a zero pivot in the Jacobi divide, or overflow after an
  indefinite step slipped through at exactly 0).

Both engines (:mod:`repro.core.batch` phases, :mod:`repro.core.vm`
specialized + generic) evaluate :func:`tick_health` on each tick's
*candidate* values: a lane that trips a predicate **freezes that tick**
— its writes are discarded, its iteration counter does not advance, and
its ``status`` latches the breakdown code.  Healthy lanes see only
compares and ``where`` selects on values the tick already computed, so
detection is bit-invisible to them (asserted by ``tests/test_health.py``
against detection-off runs and the phases oracle).

Status lattice (terminal states are latched; ``RUNNING`` is the only
non-terminal value)::

    RUNNING ──> CONVERGED              rr ≤ τ on a committed tick
            ──> MAXITER                per-lane budget exhausted
            ──> BREAKDOWN_INDEFINITE   pAp ≤ 0 on the candidate tick
            ──> BREAKDOWN_NONFINITE    rr/α/β non-finite (or rr non-
                                       finite already at warm-up)

:class:`Metrics` is the observability counterpart: a plain counter bag
(snapshotable as a dict) used by :class:`repro.serve.SolverEngine`
(engine-owned instance, ``SolverEngine.metrics()``) and by
:func:`repro.core.batch.jpcg_solve_batched` (module-global instance,
:func:`solver_metrics`), printed by ``benchmarks/run.py``.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Optional, Union

import jax.numpy as jnp
import numpy as np

__all__ = ["STATUS_RUNNING", "STATUS_CONVERGED", "STATUS_MAXITER",
           "STATUS_BREAKDOWN_INDEFINITE", "STATUS_BREAKDOWN_NONFINITE",
           "STATUS_NAMES", "BREAKDOWN_STATUSES", "status_name",
           "is_breakdown", "is_breakdown_codes", "initial_status",
           "tick_health",
           "advance_status", "finalize_status", "Metrics",
           "solver_metrics", "reset_solver_metrics"]

# ------------------------------------------------------------- status codes
#: Lane still iterating (the only non-terminal status).
STATUS_RUNNING = 0
#: ``rr ≤ τ`` on a committed tick (or already at warm-up).
STATUS_CONVERGED = 1
#: Per-lane iteration budget exhausted without convergence.
STATUS_MAXITER = 2
#: ``pAp ≤ 0`` — operand not SPD along the search direction.
STATUS_BREAKDOWN_INDEFINITE = 3
#: ``rr``/``α``/``β`` went NaN/Inf (incl. non-finite warm-up ``rr``).
STATUS_BREAKDOWN_NONFINITE = 4

STATUS_NAMES: Dict[int, str] = {
    STATUS_RUNNING: "RUNNING",
    STATUS_CONVERGED: "CONVERGED",
    STATUS_MAXITER: "MAXITER",
    STATUS_BREAKDOWN_INDEFINITE: "BREAKDOWN_INDEFINITE",
    STATUS_BREAKDOWN_NONFINITE: "BREAKDOWN_NONFINITE",
}

#: The statuses the engine's fp64 escalation policy may retry.
BREAKDOWN_STATUSES = ("BREAKDOWN_INDEFINITE", "BREAKDOWN_NONFINITE")


def status_name(code: Union[int, str]) -> str:
    """Human-readable name of a status code (names pass through)."""
    if isinstance(code, str):
        return code
    return STATUS_NAMES.get(int(code), f"UNKNOWN({int(code)})")


def is_breakdown(status: Union[int, str, None]) -> bool:
    """True iff the status (code or name) is a breakdown exit."""
    if status is None:
        return False
    return status_name(status) in BREAKDOWN_STATUSES


def is_breakdown_codes(codes) -> np.ndarray:
    """Vectorized :func:`is_breakdown` over a host array of status codes."""
    codes = np.asarray(codes)
    return ((codes == STATUS_BREAKDOWN_INDEFINITE)
            | (codes == STATUS_BREAKDOWN_NONFINITE))


# --------------------------------------------------- in-loop status algebra
def initial_status(rr, tol, *, detect: bool):
    """Warm-up status vector from the initial ``rr`` (both engines).

    ``CONVERGED`` where ``rr ≤ tol`` already holds, else ``RUNNING``;
    with ``detect`` a non-finite warm-up ``rr`` (NaN/Inf-seeded operand
    or rhs) latches ``BREAKDOWN_NONFINITE`` immediately — such a lane is
    inactive from tick 0 either way (``NaN > tol`` is False), detection
    just names the reason instead of wearing the MAXITER face.
    """
    st = jnp.where(rr <= tol, STATUS_CONVERGED,
                   STATUS_RUNNING).astype(jnp.int32)
    if detect:
        st = jnp.where(~jnp.isfinite(rr), STATUS_BREAKDOWN_NONFINITE, st)
    return st


def tick_health(keep, pap, alpha, beta, rr_new, *, detect: bool):
    """Classify one tick's candidate scalars per lane.

    Returns ``(upd, bd_indef, bd_nonf)``: ``upd`` is the commit mask —
    lanes whose tick writes land (``keep`` minus fresh breakdowns);
    ``bd_*`` flag lanes that froze this tick (``None`` when ``detect``
    is off, in which case ``upd is keep`` — the caller's dataflow is
    unchanged *by construction*, which is what makes detection-off a
    bit-exact reference).  Precedence: ``pAp ≤ 0`` wins over non-finite
    (an indefinite step at exactly 0 makes ``α`` Inf in the same tick —
    the indefiniteness is the diagnosis, the Inf the symptom); NaN
    ``pAp`` fails the ``≤ 0`` compare and lands in non-finite.

    Assumes the tick computes ``pAp`` (every compiled ISA program and
    the phase engine do); a custom VM program that never writes the
    ``pap`` scalar register must run with detection off.
    """
    if not detect:
        return keep, None, None
    bd_indef = keep & (pap <= 0)
    bad = ~(jnp.isfinite(rr_new) & jnp.isfinite(alpha) & jnp.isfinite(beta))
    bd_nonf = keep & ~bd_indef & bad
    return keep & ~(bd_indef | bd_nonf), bd_indef, bd_nonf


def advance_status(status, *, upd, bd_indef, bd_nonf, rr_new, tol, it,
                   maxiter_vec=None):
    """One tick's status transitions (shared by both engines).

    ``it`` is the already-advanced per-lane count; ``maxiter_vec`` is
    the per-lane budget when the loop enforces one in-loop (the serving
    steppers — solve runners bound ``k`` statically instead and map
    leftover ``RUNNING`` via :func:`finalize_status`).  Terminal states
    latch: every transition is gated on a mask that is ``False`` for
    lanes already frozen.
    """
    if bd_indef is not None:
        status = jnp.where(bd_indef, STATUS_BREAKDOWN_INDEFINITE, status)
        status = jnp.where(bd_nonf, STATUS_BREAKDOWN_NONFINITE, status)
    conv = upd & (rr_new <= tol)
    status = jnp.where(conv, STATUS_CONVERGED, status)
    if maxiter_vec is not None:
        status = jnp.where(upd & ~conv & (it >= maxiter_vec),
                           STATUS_MAXITER, status)
    return status


def finalize_status(status):
    """Map leftover ``RUNNING`` to ``MAXITER`` when a solve runner's loop
    exits — the only ways to leave the loop still ``RUNNING`` are the
    static ``k == maxiter`` bound and (detection off) a lane inactive
    since warm-up, both of which wear the budget-exhausted face."""
    return jnp.where(status == STATUS_RUNNING, STATUS_MAXITER, status)


# ------------------------------------------------------------- observability
class Metrics:
    """Flat counter bag + exit-status histogram, snapshotable as a dict.

    Deliberately dumb: ``bump`` adds to named integer counters,
    ``record_exit`` feeds the status histogram, ``snapshot`` returns
    plain Python data (safe to json-dump next to BENCH_*.json).  All
    host-side — nothing here touches a traced value.
    """

    def __init__(self) -> None:
        self._counters: Counter = Counter()
        self._exits: Counter = Counter()

    def reset(self) -> None:
        self._counters.clear()
        self._exits.clear()

    def bump(self, name: str, value: int = 1) -> None:
        self._counters[name] += int(value)

    def record_exit(self, status: Union[int, str],
                    count: int = 1) -> None:
        self._exits[status_name(status)] += int(count)

    def record_exits(self, statuses) -> None:
        """Histogram a whole status vector (host array of codes)."""
        codes, counts = np.unique(np.asarray(statuses), return_counts=True)
        for c, n in zip(codes, counts):
            self.record_exit(int(c), int(n))

    def get(self, name: str) -> int:
        return int(self._counters.get(name, 0))

    @property
    def exit_histogram(self) -> Dict[str, int]:
        return dict(self._exits)

    def snapshot(self, extra: Optional[dict] = None) -> dict:
        out = {k: int(v) for k, v in sorted(self._counters.items())}
        out["exit_status"] = dict(self._exits)
        if extra:
            out.update(extra)
        return out


#: Module-global metrics fed by the solve runners
#: (:func:`repro.core.batch.jpcg_solve_batched`); the serving engine owns
#: its own instance instead (``SolverEngine.metrics()``).
_GLOBAL = Metrics()


def solver_metrics() -> Metrics:
    """The process-wide solver metrics instance."""
    return _GLOBAL


def reset_solver_metrics() -> None:
    _GLOBAL.reset()
