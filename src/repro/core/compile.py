"""Schedule→program compiler — lowers VSR schedules to stream-ISA programs.

This closes the pipeline the paper only sketches: §5's vector-streaming-
reuse analysis (:mod:`repro.core.vsr`) *decides* which vectors flow through
on-chip streams versus HBM, and §4's instruction set (:mod:`repro.core.isa`)
*encodes* those decisions — but Callipepla's global controller is hand-
written per solver.  Here :func:`compile_schedule` mechanically lowers any
:class:`~repro.core.vsr.VSRSchedule` (``policy="paper"``, ``"min_traffic"``,
or a schedule of a different module graph entirely, e.g.
:data:`PLAIN_CG_MODULES`) into the ``int32[P, 8]`` word array the batched
stream VM (:mod:`repro.core.vm`) executes.  ``isa.assemble_jpcg`` is
demoted to a *golden reference*: the compiler reproduces its paper-policy
output word for word (locked by ``tests/test_compile.py``).

Lowering has two passes per phase:

1. **List scheduling** (:func:`_schedule_events`) — orders the phase's
   modules and HBM writes.  Priorities mirror the paper's controller:
   dot modules first (the §4.2 hoist of M8 so ``rr`` exists as early as
   possible for on-the-fly termination), then pending stores (a produced
   value drains to HBM as soon as it exists — M5's pass-through store),
   then remaining modules preferring (a) operands already streaming,
   (b) producers whose consumers wait in this phase, (c) schedule order.
2. **Queue allocation** (:func:`_emit_phase`) — assigns the 8 stream
   queues.  Reads mirror the VSR sharing rule exactly: a value read by a
   non-heavy module stays shareable (fan-out is free), a gather-ordered
   (heavy) read is private — the §5.2 alignment constraint that makes
   phase 1 read ``p`` twice.  Queues allocate from a fresh counter per
   phase and recycle most-recently-freed (LIFO) once all 8 are claimed,
   which reproduces the hand assembly's reuse of queue 6 for ``x'``.

Every compiled program is validated against its schedule: the emitted
per-phase read/write multisets must equal ``VSRSchedule.hbm_reads`` /
``hbm_writes``, so :func:`~repro.core.isa.derived_mem_instructions` of the
output agrees with :func:`~repro.core.vsr.access_counts` by construction
(14 = 10R+4W paper, 13 = 9R+4W min-traffic).

Programs are *operands*, not code: the VM executable is compiled per
(bucket shape, backend, precision scheme) and any program of the same
padded length runs on it with no retrace.  :func:`canonical_program` pads
to one shared length so paper / min-traffic / plain-CG programs all hit
the same executable — the JAX analogue of one bitstream serving every
schedule.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.isa import (BUF, ITYPE_COMP, ITYPE_CTRL, ITYPE_VCTRL, MOD,
                            SREG, CTRL_ALPHA, CTRL_BETA, Instr, pad_program,
                            program_token)
from repro.core.vsr import (JPCG_MODULES, LOOP_CARRIED, Module, VSRSchedule,
                            schedule)

__all__ = ["CompileError", "CompiledProgram", "compile_schedule",
           "compile_policy", "canonical_program", "canonical_length",
           "executable_key", "PLAIN_CG_MODULES", "OPSPECS", "OpSpec"]

_N_QUEUES = 8


class CompileError(ValueError):
    """The schedule cannot be lowered to the stream ISA."""


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """ISA-level semantics of one module name.

    ``kind`` selects the VM's compute branch; ``sreg`` is the scalar
    register an axpy reads / a dot writes; ``operand_order`` permutes the
    module's declared ``reads`` into ISA (qa, qb) order — e.g. M5 is
    declared ``reads=("M", "r'")`` but divides r'/M.
    """

    kind: str                                  # spmv | dot | axpy | div
    sreg: Optional[str] = None
    neg: bool = False
    operand_order: Optional[Tuple[int, ...]] = None


#: ISA semantics per module name (shared by every module graph that reuses
#: the M1–M8 vocabulary — the VM's branch table is fixed, like the FPGA's).
OPSPECS: Dict[str, OpSpec] = {
    "M1_spmv":    OpSpec("spmv"),
    "M2_dot_pap": OpSpec("dot", "pap"),
    "M3_upd_x":   OpSpec("axpy", "alpha"),
    "M4_upd_r":   OpSpec("axpy", "alpha", neg=True),
    "M5_div_z":   OpSpec("div", operand_order=(1, 0)),   # z = r' / M
    "M6_dot_rz":  OpSpec("dot", "rz_new"),
    "M7_upd_p":   OpSpec("axpy", "beta"),
    "M8_dot_rr":  OpSpec("dot", "rr"),
}

#: scalars the controller derives from dot results (paper Type-II → CTRL).
_CTRL_OF_SCALAR = {"alpha": CTRL_ALPHA, "beta": CTRL_BETA}


#: Plain (non-preconditioned) CG on the same module vocabulary: M5 is gone
#: (z ≡ r'), M6 dots r'·r' for β, M7 updates p from r' directly.  With a
#: unit diagonal this iterates identically to JPCG — the VM-level witness
#: that the compiler serves module graphs beyond the paper's.
PLAIN_CG_MODULES: Tuple[Module, ...] = (
    Module("M1_spmv",    reads=("p",),       writes=("ap",), heavy=True),
    Module("M2_dot_pap", reads=("p", "ap"),  writes=(), scalar_out="alpha"),
    Module("M3_upd_x",   reads=("x", "p"),   writes=("x'",),
           scalar_in=("alpha",)),
    Module("M4_upd_r",   reads=("r", "ap"),  writes=("r'",),
           scalar_in=("alpha",)),
    Module("M6_dot_rz",  reads=("r'",),      writes=(), scalar_out="beta"),
    Module("M7_upd_p",   reads=("r'", "p"),  writes=("p'",),
           scalar_in=("beta",)),
    Module("M8_dot_rr",  reads=("r'",),      writes=(), scalar_out="rr"),
)


def _buf(vec: str) -> int:
    """HBM buffer id of a vector name (primed names alias their buffer)."""
    base = LOOP_CARRIED.get(vec, vec)
    if base not in BUF:
        raise CompileError(
            f"vector {vec!r} has no HBM buffer (never-stored intermediates "
            "cannot be read from or written to memory)")
    return BUF[base]


def _operands(m: Module) -> Tuple[str, ...]:
    spec = OPSPECS[m.name]
    if spec.operand_order is not None:
        return tuple(m.reads[i] for i in spec.operand_order)
    return m.reads


# --------------------------------------------------------------- pass A
def _schedule_events(active: Sequence[str], writes: Sequence[str],
                     by_name: Dict[str, Module]) -> List[Tuple[str, str]]:
    """Order one phase's modules + HBM stores into an event list.

    Returns ``[("comp", module_name) | ("write", vec_name), ...]``.
    """
    mods = list(active)
    produced_by = {v: n for n in mods for v in by_name[n].writes}
    pending_writes = list(writes)
    has_consumer = {
        n: any(v in by_name[o].reads for o in mods if o != n
               for v in by_name[n].writes)
        for n in mods}

    emitted: List[Tuple[str, str]] = []
    done_mods: set = set()
    done_writes: Counter = Counter()
    live: set = set()          # values currently available in a queue
    read_shareable: set = set()

    def mod_ready(n: str) -> bool:
        return all(v not in produced_by or produced_by[v] in done_mods
                   for v in by_name[n].reads)

    def live_operands(n: str) -> int:
        return sum(1 for v in by_name[n].reads
                   if v in live or v in read_shareable)

    while len(done_mods) < len(mods) or sum(done_writes.values()) < len(
            pending_writes):
        ready_mods = [n for n in mods if n not in done_mods and mod_ready(n)]
        ready_writes = [v for v in pending_writes
                        if done_writes[v] < pending_writes.count(v)
                        and v in produced_by and produced_by[v] in done_mods]
        # 1. dot modules (scalar producers) — the M8 early-termination hoist
        dots = [n for n in ready_mods if OPSPECS[n].kind == "dot"]
        if dots:
            pick = dots[0]
        elif ready_writes:
            # 2. drain produced values to HBM as soon as they exist
            emitted.append(("write", ready_writes[0]))
            done_writes[ready_writes[0]] += 1
            continue
        elif ready_mods:
            # 3. prefer consuming live streams, then unblocking consumers
            pick = max(ready_mods,
                       key=lambda n: (live_operands(n), has_consumer[n],
                                      -mods.index(n)))
        else:
            raise CompileError(
                f"phase deadlock: modules {set(mods) - done_mods} never "
                "become ready (cyclic intra-phase dependency?)")
        emitted.append(("comp", pick))
        done_mods.add(pick)
        m = by_name[pick]
        for v in m.reads:
            if v not in produced_by and v not in read_shareable:
                if not m.heavy:
                    read_shareable.add(v)
        live.update(m.writes)
        continue
    return emitted


# --------------------------------------------------------------- pass B
def _emit_phase(events: List[Tuple[str, str]],
                by_name: Dict[str, Module]) -> Tuple[
                    List[Instr], List[str], List[str]]:
    """Assign queues and emit instructions for one phase's event list."""
    instrs: List[Instr] = []
    reads_emitted: List[str] = []
    writes_emitted: List[str] = []
    live: Dict[str, int] = {}        # value -> queue holding it
    shareable: Dict[str, bool] = {}  # read values: stream-shareable?
    remaining: Dict[int, int] = {}   # queue -> outstanding uses
    next_q = 0
    free: List[int] = []             # LIFO recycle stack

    def alloc() -> int:
        nonlocal next_q
        if next_q < _N_QUEUES:
            q = next_q
            next_q += 1
            return q
        if not free:
            raise CompileError("stream-queue pressure exceeds 8 FIFOs")
        return free.pop()

    def future_uses(start: int, vec: str, *, share: bool) -> int:
        """Queue uses of ``vec`` by events at index > start."""
        uses = 0
        for kind, name in events[start + 1:]:
            if kind == "comp" and share:
                uses += sum(1 for v in _operands(by_name[name]) if v == vec)
            elif kind == "write" and name == vec:
                uses += 1
        return uses

    def consume(q: int, vec: str) -> None:
        remaining[q] -= 1
        if remaining[q] == 0:
            free.append(q)
            del remaining[q]
            if live.get(vec) == q:
                del live[vec]

    for idx, (kind, name) in enumerate(events):
        if kind == "write":
            q = live.get(name)
            if q is None:
                raise CompileError(f"store of {name!r} before it exists")
            instrs.append(Instr(ITYPE_VCTRL, _buf(name), wr=1, qa=q))
            writes_emitted.append(name)
            consume(q, name)
            continue

        m = by_name[name]
        spec = OPSPECS[m.name]
        ops = _operands(m)
        qs: List[int] = []
        for v in ops:
            if v in live:
                qs.append(live[v])
                continue
            q = alloc()
            instrs.append(Instr(ITYPE_VCTRL, _buf(v), rd=1, qd=q))
            reads_emitted.append(v)
            live[v] = q
            share = not m.heavy          # §5.2 alignment constraint
            shareable[v] = share
            remaining[q] = 1 + (future_uses(idx, v, share=share)
                                if share else 0)
            qs.append(q)

        if spec.kind == "spmv":
            qd = alloc()
            out, = m.writes
            live[out] = qd
            remaining[qd] = future_uses(idx, out, share=True)
            instrs.append(Instr(ITYPE_COMP, MOD[m.name], qa=qs[0], qd=qd))
        elif spec.kind == "dot":
            qa = qs[0]
            qb = qs[1] if len(qs) > 1 else qs[0]
            instrs.append(Instr(ITYPE_COMP, MOD[m.name], qa=qa, qb=qb,
                                sreg=SREG[spec.sreg]))
        else:                            # axpy / div: dst = a (op s·) b
            qd = alloc()                 # claim output before inputs drain
            out, = m.writes
            live[out] = qd
            remaining[qd] = future_uses(idx, out, share=True)
            sreg = SREG[spec.sreg] if spec.sreg else 0
            instrs.append(Instr(ITYPE_COMP, MOD[m.name], rd=int(spec.neg),
                                qa=qs[0], qb=qs[1], qd=qd, sreg=sreg))
        for v, q in zip(ops, qs):
            if q in remaining:
                consume(q, v)
    return instrs, reads_emitted, writes_emitted


# ---------------------------------------------------------------- driver
@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """A lowered schedule: the int32[P, 8] word array + its provenance."""

    policy: str
    program: np.ndarray
    instrs: Tuple[Instr, ...]
    source: VSRSchedule

    @property
    def length(self) -> int:
        return int(self.program.shape[0])

    @property
    def cache_token(self) -> str:
        """Stable content hash of the (unpadded) program words.

        The specialized VM path keys its executables on
        ``(bucket, backend, scheme, chunk, program bytes)`` — this token
        is the last component.  Note the *padded* words are what actually
        run; :func:`repro.core.isa.program_token` of the padded array is
        what the runner/stepper caches use, and two ``CompiledProgram``\\ s
        with equal ``cache_token`` pad to equal bytes.
        """
        return program_token(self.program)

    def padded(self, length: int) -> np.ndarray:
        """NOP-pad to ``length`` (programs of one length share one VM)."""
        return pad_program(self.program, length)


def compile_schedule(sched: VSRSchedule,
                     modules: Sequence[Module] = JPCG_MODULES,
                     ) -> CompiledProgram:
    """Lower a VSR schedule to a stream-ISA program.

    Raises :class:`CompileError` if the emitted HBM traffic disagrees with
    the schedule's ``hbm_reads``/``hbm_writes`` plan — the compiler must
    implement exactly the traffic the analyzer promised.
    """
    by_name = {m.name: m for m in modules}
    missing = [n for p in sched.phases for n in p if n not in OPSPECS]
    if missing:
        raise CompileError(f"modules without ISA semantics: {missing}")

    instrs: List[Instr] = []
    for p, active in enumerate(sched.phases):
        events = _schedule_events(active, sched.hbm_writes[p], by_name)
        phase_instrs, reads, writes = _emit_phase(events, by_name)
        if Counter(reads) != Counter(sched.hbm_reads[p]):
            raise CompileError(
                f"phase {p}: emitted reads {sorted(reads)} != scheduled "
                f"{sorted(sched.hbm_reads[p])}")
        if Counter(writes) != Counter(sched.hbm_writes[p]):
            raise CompileError(
                f"phase {p}: emitted writes {sorted(writes)} != scheduled "
                f"{sorted(sched.hbm_writes[p])}")
        instrs.extend(phase_instrs)
        for name in (n for k, n in events if k == "comp"):
            s = by_name[name].scalar_out
            if s in _CTRL_OF_SCALAR:
                instrs.append(Instr(ITYPE_CTRL, _CTRL_OF_SCALAR[s]))

    enc = np.asarray([i.encode() for i in instrs], dtype=np.int32)
    return CompiledProgram(policy=sched.policy, program=enc,
                           instrs=tuple(instrs), source=sched)


@lru_cache(maxsize=None)
def compile_policy(policy: str = "paper",
                   modules: Tuple[Module, ...] = JPCG_MODULES,
                   ) -> CompiledProgram:
    """Compile ``vsr.schedule(modules, policy)`` (memoized — programs are
    pure functions of (policy, module graph))."""
    return compile_schedule(schedule(modules, policy=policy), modules)


@lru_cache(maxsize=None)
def canonical_length(modules: Tuple[Module, ...] = JPCG_MODULES) -> int:
    """Shared padded program length across this graph's policies — every
    policy's program NOP-pads to this, so one compiled VM runs them all."""
    return max(compile_policy(p, modules).length
               for p in ("paper", "min_traffic"))


def canonical_program(policy: str = "paper",
                      modules: Tuple[Module, ...] = JPCG_MODULES,
                      ) -> np.ndarray:
    """Compile ``policy`` and pad to the graph's canonical shared length."""
    return compile_policy(policy, modules).padded(canonical_length(modules))


def executable_key(kind: str, *, backend: str, scheme: str, bucket,
                   steps_per_sync: int, donate: bool, interpret: bool,
                   layout: Optional[str] = None,
                   index_bytes: Optional[int] = None,
                   batch: Optional[int] = None,
                   maxiter: Optional[int] = None,
                   chunk: Optional[int] = None,
                   with_trace: Optional[bool] = None,
                   detect: Optional[bool] = None,
                   mesh=None,
                   program: Optional[np.ndarray] = None) -> tuple:
    """Canonical executable-cache key for VM/phases runners and steppers.

    One function builds every key so the fields that *must* split
    executables are impossible to forget at any call site:

    ========================  ==================================================
    field                     why it splits executables
    ========================  ==================================================
    ``kind``                  runner vs stepper, specialized vs generic
    ``backend`` ``scheme``    different kernels / cast chains
    ``bucket``                padded operand shape (row-ELL ``(n_pad, W)`` on
                              XLA, sliced-ELL ``(n_pad, rows0, w0, rows1, w1,
                              ...)`` — the static group signature — on either
                              backend, ``(B, T, E, n_tiles)`` on Pallas)
    ``layout``                matrix operand format (``rowell`` / ``sell`` /
                              ``ellpack``) — different gather/reduce graphs
                              even at equal bucket dims (ISSUE 8)
    ``index_bytes``           stored column-index width (2 = int16 when
                              ``n_pad < 2^15``, else 4) — changes the operand
                              dtype the executable is traced for (ISSUE 8)
    ``batch``/``maxiter``/    solve-runner shape + static loop bound /
    ``with_trace``            trace width
    ``chunk``                 stepper iteration budget (static)
    ``steps_per_sync``        iteration-chunking factor — baked into the loop
                              body structure (ISSUE 7)
    ``donate``                donation changes the jit wrapper, not just args
    ``detect``                breakdown detection adds status compares/selects
                              to the loop body (ISSUE 9); the carried
                              ``status`` vector itself is key-neutral — both
                              variants carry it
    ``interpret``             Pallas interpreter vs compiled kernel
    ``mesh``                  lane-sharding signature, folded to
                              :func:`repro.core.shard.mesh_signature`
                              (``None`` = unsharded) — a sharded executable
                              bakes SPMD operand layouts in at trace time, so
                              single-device and mesh variants (and different
                              mesh sizes) must never collide (ISSUE 10)
    ``program``               folded to :func:`repro.core.isa.program_token`;
                              only present for *specialized* executables —
                              generic ones deliberately omit it so any program
                              of one padded length reuses one executable
    ========================  ==================================================
    """
    from repro.core.shard import mesh_signature
    key = (kind, backend, scheme, batch, tuple(np.ravel(bucket).tolist()),
           layout, index_bytes, maxiter, chunk, with_trace,
           int(steps_per_sync), bool(donate),
           None if detect is None else bool(detect), bool(interpret),
           mesh_signature(mesh))
    if program is not None:
        key += (program_token(np.asarray(program, np.int32)),)
    return key
