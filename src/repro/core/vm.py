"""Batched stream VM — executes stream-centric ISA programs (paper §3–§4)
for G independent systems at once, inside one compiled loop.

The VM models Callipepla's top architecture (paper Fig. 1), widened by a
lane dimension so it can serve the batched solver and the serving engine
directly — this is the *single solver backend*; the phase-fused loop in
:mod:`repro.core.phases` remains as its bit-exact oracle:

* **memory** — the HBM vector buffers (x, r, p, ap, M, b) as one
  ``[6, G, n]`` array: buffer id × lane × element;
* **queues** — the inter-module FIFOs, ``[8, G, n]``; a queue register
  holds one logical vector in flight per lane (fan-out is free, like the
  paper's VecCtrl element duplication);
* **computation modules** M1–M8 dispatched by ``lax.switch`` — M1 routes
  through the same batched SpMV closures as the phase engine
  (:func:`repro.core.batch._matvec_factory`: XLA flat-stream or Pallas
  ELLPACK), M2/M6/M8 are row-wise dot modules writing ``[G]`` scalar
  registers, M3/M4/M7 the axpy family, M5 the Jacobi left-divide;
* **global controller** — an outer ``lax.while_loop`` that runs the
  program once per iteration and terminates each lane on the fly at its
  own ``rr_g ≤ τ_g`` (paper Challenge 1, batched): every state write is
  gated on the lane's ``active`` flag exactly like
  :func:`repro.core.batch._batched_body`, so a converged lane's buffers
  freeze mid-batch while the survivors keep iterating.

The program is a *traced operand*: one compiled VM executable (cached per
(bucket shape, backend, precision scheme) — plus the chunk size for the
serving stepper — in the batch compile cache; the key deliberately
excludes the program) runs paper-policy,
min-traffic, plain-CG, or any other program of the same padded length
with **no retrace** — the JAX analogue of not re-running synthesis/
place/route per problem.  ``tests/test_compile.py`` asserts bit-level
agreement with the phase engine and trace-count invariance across
programs; the front doors are :func:`repro.core.batch.jpcg_solve_batched`
(``engine="vm"``, the default) and :class:`repro.serve.SolverEngine`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import _cached, _matvec_factory, _row_dot
from repro.core.isa import BUF, SREG
from repro.core.precision import get_scheme

__all__ = ["BatchedVMState", "make_vm_runner", "make_vm_stepper",
           "vm_executable_stats", "vm_solve"]

_N_QUEUES = 8
_N_SREGS = 6


class BatchedVMState(NamedTuple):
    """Lane-batched VM state; every array's lane axis is G."""

    k: jax.Array         # global tick (int32 scalar)
    it: jax.Array        # int32[G] per-lane iteration counts
    mem: jax.Array       # [6, G, n] HBM vector buffers (x r p ap M b)
    queues: jax.Array    # [8, G, n] inter-module streams
    sregs: jax.Array     # [6, G] scalar registers (α β rz rr pap rz')
    active: jax.Array    # bool[G] live-lane mask
    trace: jax.Array     # [G, maxiter] rr per iteration, or [G, 0]


def _make_executor(matvec):
    """Per-instruction executor closed over the batched SpMV closure."""

    def exec_vctrl(w, st: BatchedVMState) -> BatchedVMState:
        buf, rd, wr, qa, qd = w[1], w[2], w[3], w[4], w[6]
        # rd: queue[qd] <- mem[buf] ; wr: mem[buf] <- queue[qa]
        q = jax.lax.cond(
            rd == 1,
            lambda: st.queues.at[qd].set(st.mem[buf]),
            lambda: st.queues)
        m = jax.lax.cond(
            wr == 1,
            lambda: st.mem.at[buf].set(st.queues[qa]),
            lambda: st.mem)
        return st._replace(mem=m, queues=q)

    def exec_comp(w, st: BatchedVMState) -> BatchedVMState:
        mod, neg, qa, qb, qd, sr = w[1], w[2], w[4], w[5], w[6], w[7]
        a = st.queues[qa]                       # [G, n]
        bq = st.queues[qb]
        s = st.sregs[sr]                        # [G]
        s = jnp.where(neg == 1, -s, s)

        def spmv():      # M1
            return st.queues.at[qd].set(matvec(a)), st.sregs

        def dot():       # M2 / M6 / M8 -> scalar register (row-wise)
            return st.queues, st.sregs.at[sr].set(_row_dot(a, bq))

        def axpy():      # M3 / M4 / M7: dst = a + s·b (per lane)
            return st.queues.at[qd].set(a + s[:, None] * bq), st.sregs

        def div():       # M5: dst = a / b  (Jacobi left-divide)
            return st.queues.at[qd].set(a / bq), st.sregs

        branch = jnp.array([0, 1, 2, 2, 3, 1, 2, 1], jnp.int32)[mod]
        q, sregs = jax.lax.switch(branch, [spmv, dot, axpy, div])
        return st._replace(queues=q, sregs=sregs)

    def exec_ctrl(w, st: BatchedVMState) -> BatchedVMState:
        def alpha():     # α = rz / pap, per lane
            return st.sregs.at[SREG["alpha"]].set(
                st.sregs[SREG["rz"]] / st.sregs[SREG["pap"]])

        def beta():      # β = rz' / rz ; rz ← rz'
            s = st.sregs.at[SREG["beta"]].set(
                st.sregs[SREG["rz_new"]] / st.sregs[SREG["rz"]])
            return s.at[SREG["rz"]].set(st.sregs[SREG["rz_new"]])

        return st._replace(sregs=jax.lax.switch(w[1], [alpha, beta]))

    def exec_nop(w, st: BatchedVMState) -> BatchedVMState:
        return st

    def execute(w, st: BatchedVMState) -> BatchedVMState:
        return jax.lax.switch(
            w[0], [lambda: exec_vctrl(w, st), lambda: exec_comp(w, st),
                   lambda: exec_ctrl(w, st), lambda: exec_nop(w, st)])

    return execute


def vm_init(matvec, diag, b, x0, *, maxiter: int, with_trace: bool,
            tol) -> BatchedVMState:
    """Controller warm-up (paper Alg. 1 lines 1–5) — arithmetic identical
    to :func:`repro.core.batch._batched_init`, packed into VM buffers."""
    vd = b.dtype
    G = b.shape[0]
    r = b - matvec(x0)
    z = r / diag
    rz = _row_dot(r, z)
    rr = _row_dot(r, r)
    mem = jnp.stack([x0, r, z, jnp.zeros_like(r), diag, b])  # x r p ap M b
    sregs = jnp.zeros((_N_SREGS, G), vd)
    sregs = sregs.at[SREG["rz"]].set(rz).at[SREG["rr"]].set(rr)
    return BatchedVMState(
        k=jnp.zeros((), jnp.int32), it=jnp.zeros(G, jnp.int32), mem=mem,
        queues=jnp.zeros((_N_QUEUES,) + r.shape, vd), sregs=sregs,
        active=rr > tol,
        trace=jnp.zeros((G, maxiter if with_trace else 0), vd))


def _vm_body(program, matvec, tol, maxiter_vec=None):
    """One VM tick = run the program once = one JPCG iteration per lane.

    Frozen (converged) lanes flow through the arithmetic — dead compute
    on a SIMD device — but ``mem``/``sregs`` writes are gated on
    ``active``, mirroring the masking semantics of
    :func:`repro.core.batch._batched_body` bit for bit.
    """
    execute = _make_executor(matvec)

    def body(st: BatchedVMState) -> BatchedVMState:
        def step(pc, s):
            return execute(program[pc], s)

        nxt = jax.lax.fori_loop(0, program.shape[0], step, st)
        keep = st.active
        mem = jnp.where(keep[None, :, None], nxt.mem, st.mem)
        sregs = jnp.where(keep[None, :], nxt.sregs, st.sregs)
        it = st.it + keep.astype(jnp.int32)
        rr = sregs[SREG["rr"]]
        if st.trace.shape[1]:
            trace = st.trace.at[:, st.k].set(
                jnp.where(keep, nxt.sregs[SREG["rr"]], st.trace[:, st.k]))
        else:
            trace = st.trace
        active = keep & (rr > tol)
        if maxiter_vec is not None:
            active = active & (it < maxiter_vec)
        return BatchedVMState(k=st.k + 1, it=it, mem=mem,
                              queues=nxt.queues, sregs=sregs,
                              active=active, trace=trace)

    return body


# ------------------------------------------------------------ executables
def make_vm_runner(*, backend, scheme, maxiter, with_trace, block_rows,
                   col_tile, n_col_tiles, n_row_blocks, interpret=False):
    """Build the jitted solve-to-completion VM runner for one bucket.

    Returns ``run(program, mat, diag, b, x0, tol) -> BatchedVMState``.
    The program is a runtime operand: callers cache this runner keyed on
    the *bucket*, never on the program or VSR policy.
    """
    scheme = get_scheme(scheme)
    matvec_of = _matvec_factory(
        backend=backend, scheme=scheme, block_rows=block_rows,
        col_tile=col_tile, n_col_tiles=n_col_tiles,
        n_row_blocks=n_row_blocks, interpret=interpret)

    @jax.jit
    def run(program, mat, diag, b, x0, tol):
        matvec = matvec_of(mat)
        st = vm_init(matvec, diag, b, x0, maxiter=maxiter,
                     with_trace=with_trace, tol=tol)
        body = _vm_body(program, matvec, tol)

        def cond(s):
            return (s.k < maxiter) & jnp.any(s.active)

        return jax.lax.while_loop(cond, body, st)

    return run


def make_vm_stepper(*, backend, scheme, block_rows, col_tile, n_col_tiles,
                    n_row_blocks, chunk, interpret=False):
    """Jitted bounded VM stepper for incremental serving (SolverEngine).

    Runs at most ``chunk`` program executions (= iterations) from a given
    state; per-lane budgets come in as ``maxiter_vec``.  Cached in the
    batch compile cache keyed on (backend, scheme, bucket, chunk) — NOT
    on the program, so every policy's program reuses one executable.
    Returns ``step(program, mat, state, tol, maxiter_vec) -> state``
    (no separate diag operand — the preconditioner lives in ``mem[M]``).
    """
    scheme = get_scheme(scheme)
    key = ("vm_step", backend, scheme.name, block_rows, col_tile,
           n_col_tiles, n_row_blocks, chunk, interpret)

    def make():
        matvec_of = _matvec_factory(
            backend=backend, scheme=scheme, block_rows=block_rows,
            col_tile=col_tile, n_col_tiles=n_col_tiles,
            n_row_blocks=n_row_blocks, interpret=interpret)

        @jax.jit
        def step(program, mat, state, tol, maxiter_vec):
            matvec = matvec_of(mat)
            body = _vm_body(program, matvec, tol, maxiter_vec)
            start = state.k

            def cond(s):
                return (s.k - start < chunk) & jnp.any(s.active)

            return jax.lax.while_loop(cond, body, state)

        return step

    return _cached(key, make)


def vm_executable_stats() -> dict:
    """VM executables in the batch compile cache + total traced shapes.

    ``traces`` counts jit cache entries across all VM runners/steppers:
    running a *different program* through an existing executable must not
    change it (the no-retrace acceptance check); only a new bucket shape,
    backend, scheme, or program *length* may.
    """
    from repro.core.batch import _CACHE
    fns = [fn for k, fn in _CACHE.items()
           if isinstance(k, tuple) and k and str(k[0]).startswith("vm_")]
    return {"executables": len(fns),
            "traces": int(sum(f._cache_size() for f in fns))}


# ---------------------------------------------------------------- public
def vm_solve(a, b=None, x0=None, *, program: np.ndarray, tol: float = 1e-12,
             maxiter: int = 20_000, scheme="mixed_v3",
             block_rows: int = 256, col_tile: int = 512,
             backend: str = "xla", interpret: Optional[bool] = None) -> dict:
    """Solve Ax=b by executing ``program`` on the stream VM (batch of 1).

    Thin wrapper over :func:`repro.core.batch.jpcg_solve_batched` with
    ``engine="vm"`` — the single-system view of the one solver backend.
    """
    from repro.core.batch import jpcg_solve_batched
    res = jpcg_solve_batched(
        [a], None if b is None else [b], None if x0 is None else [x0],
        tol=tol, maxiter=maxiter, scheme=scheme, backend=backend,
        engine="vm", program=program, block_rows=block_rows,
        col_tile=col_tile, interpret=interpret)[0]
    return {"x": res.x, "iterations": res.iterations, "rr": res.rr,
            "converged": res.converged}
