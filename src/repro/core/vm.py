"""Stream VM — executes stream-centric ISA programs (paper §3–§4).

The VM models Callipepla's top architecture (paper Fig. 1):

* **memory** — a bank of named HBM vector buffers (x, r, p, ap, M, b);
* **queues** — the inter-module FIFOs; since our "streaming" happens inside
  fused XLA regions, a queue register holds one logical vector in flight
  (fan-out is free, like the paper's VecCtrl element duplication);
* **computation modules** M1–M8 dispatched by ``lax.switch`` — M1 is the
  mixed-precision SpMV, M2/M6/M8 the dot modules, M3/M4/M7 the axpy
  family, M5 the Jacobi left-divide;
* **global controller** — an outer ``lax.while_loop`` that runs the
  program once per iteration, updates the scalar registers (α, β, rz, rr)
  via CTRL instructions, and terminates on the fly when ``rr ≤ τ``
  (paper Challenge 1).

The program is a *traced operand*: one compiled VM executes any program of
the ISA (paper-policy, min-traffic, or anything else assembled from the
module vocabulary) with **no retrace** — the JAX analogue of not re-running
synthesis/place/route per problem.  ``tests/test_vm.py`` asserts bit-level
agreement with the production solver and that NOP-padded program variants
share one executable.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.isa import (ITYPE_COMP, ITYPE_CTRL, ITYPE_NOP, ITYPE_VCTRL,
                            BUF, SREG)
from repro.core.operators import as_operator
from repro.core.precision import get_scheme

__all__ = ["VMState", "vm_solve"]

_N_QUEUES = 8
_N_SREGS = 6


class VMState(NamedTuple):
    mem: jax.Array       # [6, n] HBM vector buffers
    queues: jax.Array    # [8, n] inter-module streams
    sregs: jax.Array     # [6]    scalar registers (alpha, beta, rz, rr, pap, rz')
    i: jax.Array         # iteration counter


def _make_executor(op, vd):
    """Build the per-instruction executor closed over the SpMV operator."""

    def exec_vctrl(w, st: VMState) -> VMState:
        buf, rd, wr, qa, qd = w[1], w[2], w[3], w[4], w[6]
        # rd: queue[qd] <- mem[buf] ; wr: mem[buf] <- queue[qa]
        q = jax.lax.cond(
            rd == 1,
            lambda: st.queues.at[qd].set(st.mem[buf]),
            lambda: st.queues)
        m = jax.lax.cond(
            wr == 1,
            lambda: st.mem.at[buf].set(st.queues[qa]),
            lambda: st.mem)
        return st._replace(mem=m, queues=q)

    def exec_comp(w, st: VMState) -> VMState:
        mod, neg, qa, qb, qd, sr = w[1], w[2], w[4], w[5], w[6], w[7]
        a = st.queues[qa]
        bq = st.queues[qb]
        s = st.sregs[sr]
        s = jnp.where(neg == 1, -s, s)

        def spmv():      # M1
            return st.queues.at[qd].set(op.matvec(a)), st.sregs

        def dot():       # M2 / M6 / M8 -> scalar register
            return st.queues, st.sregs.at[sr].set(jnp.dot(a, bq))

        def axpy():      # M3 / M4 / M7: dst = a + s·b
            return st.queues.at[qd].set(a + s * bq), st.sregs

        def div():       # M5: dst = a / b  (Jacobi left-divide)
            return st.queues.at[qd].set(a / bq), st.sregs

        branch = jnp.array([0, 1, 2, 2, 3, 1, 2, 1], jnp.int32)[mod]
        q, sregs = jax.lax.switch(branch, [spmv, dot, axpy, div])
        return st._replace(queues=q, sregs=sregs)

    def exec_ctrl(w, st: VMState) -> VMState:
        def alpha():     # α = rz / pap
            return st.sregs.at[SREG["alpha"]].set(
                st.sregs[SREG["rz"]] / st.sregs[SREG["pap"]])

        def beta():      # β = rz' / rz ; rz ← rz'
            s = st.sregs.at[SREG["beta"]].set(
                st.sregs[SREG["rz_new"]] / st.sregs[SREG["rz"]])
            return s.at[SREG["rz"]].set(st.sregs[SREG["rz_new"]])

        return st._replace(sregs=jax.lax.switch(w[1], [alpha, beta]))

    def exec_nop(w, st: VMState) -> VMState:
        return st

    def execute(w, st: VMState) -> VMState:
        return jax.lax.switch(
            w[0], [lambda: exec_vctrl(w, st), lambda: exec_comp(w, st),
                   lambda: exec_ctrl(w, st), lambda: exec_nop(w, st)])

    return execute


@partial(jax.jit, static_argnames=("tol", "maxiter", "scheme_name"))
def _vm_run(program, op, mem0, sregs0, *, tol, maxiter, scheme_name):
    scheme = get_scheme(scheme_name)
    vd = scheme.vector_dtype
    n = mem0.shape[1]
    execute = _make_executor(op, vd)
    st0 = VMState(mem=mem0, queues=jnp.zeros((_N_QUEUES, n), vd),
                  sregs=sregs0, i=jnp.zeros((), jnp.int32))

    def run_program(st: VMState) -> VMState:
        def step(pc, s):
            return execute(program[pc], s)
        return jax.lax.fori_loop(0, program.shape[0], step, st)

    def cond(st: VMState):
        return (st.i < maxiter) & (st.sregs[SREG["rr"]] > tol)

    def body(st: VMState):
        st = run_program(st)
        return st._replace(i=st.i + 1)

    return jax.lax.while_loop(cond, body, st0)


def vm_solve(a, b=None, x0=None, *, program: np.ndarray, tol: float = 1e-12,
             maxiter: int = 20_000, scheme="mixed_v3", diag=None,
             block_rows: int = 256, col_tile: int = 512):
    """Solve Ax=b by executing ``program`` on the stream VM."""
    scheme = get_scheme(scheme)
    vd = scheme.vector_dtype
    op = as_operator(a, scheme, diag=diag, block_rows=block_rows,
                     col_tile=col_tile)
    n = op.n
    b = (jnp.ones(n, vd) if b is None else jnp.asarray(b)).astype(vd)
    x0 = (jnp.zeros(n, vd) if x0 is None else jnp.asarray(x0)).astype(vd)
    d = jnp.asarray(op.diag).astype(vd)

    # Controller warm-up (paper merges Alg.1 lines 1–5 into the loop via the
    # rp = −1 pass; we run them directly, like the production solver).
    r0 = b - op.matvec(x0)
    z0 = r0 / d
    mem0 = jnp.stack([x0, r0, z0, jnp.zeros_like(r0), d, b])  # x r p ap M b
    sregs0 = jnp.zeros(_N_SREGS, vd)
    sregs0 = sregs0.at[SREG["rz"]].set(jnp.dot(r0, z0))
    sregs0 = sregs0.at[SREG["rr"]].set(jnp.dot(r0, r0))

    st = _vm_run(jnp.asarray(program), op, mem0, sregs0, tol=tol,
                 maxiter=maxiter, scheme_name=scheme.name)
    return {
        "x": st.mem[BUF["x"]],
        "iterations": int(st.i),
        "rr": float(st.sregs[SREG["rr"]]),
        "converged": bool(st.sregs[SREG["rr"]] <= tol),
    }
