"""Batched stream VM — executes stream-centric ISA programs (paper §3–§4)
for G independent systems at once, inside one compiled loop.

The VM models Callipepla's top architecture (paper Fig. 1), widened by a
lane dimension so it can serve the batched solver and the serving engine
directly — this is the *single solver backend*; the phase-fused loop in
:mod:`repro.core.phases` remains as its bit-exact oracle:

* **memory** — the HBM vector buffers (x, r, p, ap, M, b) as one
  ``[6, G, n]`` array: buffer id × lane × element;
* **queues** — the inter-module FIFOs, ``[8, G, n]``; a queue register
  holds one logical vector in flight per lane (fan-out is free, like the
  paper's VecCtrl element duplication);
* **computation modules** M1–M8 — M1 routes through the same batched
  SpMV closures as the phase engine
  (:func:`repro.core.batch._matvec_factory`: XLA flat-stream or Pallas
  ELLPACK), M2/M6/M8 are row-wise dot modules writing ``[G]`` scalar
  registers, M3/M4/M7 the axpy family, M5 the Jacobi left-divide;
* **global controller** — an outer ``lax.while_loop`` that runs the
  program once per iteration and terminates each lane on the fly at its
  own ``rr_g ≤ τ_g`` (paper Challenge 1, batched): every state write —
  ``mem``, ``sregs``, **and** ``queues`` — is gated on the lane's
  ``active`` flag exactly like :func:`repro.core.batch._batched_body`,
  so a converged lane's *entire* VM state freezes mid-batch while the
  survivors keep iterating.

Two execution paths share the VM's semantics:

* **specialized** (the production default) — when the program is a
  concrete ``np.ndarray`` at Python time (it always is for the front
  doors: :func:`repro.core.batch.jpcg_solve_batched` and
  :class:`repro.serve.SolverEngine` both obtain it from
  :func:`repro.core.compile.canonical_program`), the program is unrolled
  at *trace time* into straight-line jnp ops with static buffer/queue
  indices: no ``lax.switch``, no per-word ``lax.cond``, no dynamic
  gather/scatter over monolithic state.  The ``[8, G, n]`` queue file is
  decomposed into per-queue ``[G, n]`` arrays and the ``[6, G, n]``
  memory file into per-buffer arrays, so only state the program actually
  touches enters the loop-carried dataflow and XLA fuses a whole
  iteration the way :func:`repro.core.phases.vsr_iteration` fuses — the
  JAX analogue of the FPGA paying dispatch once at synthesis.
  Executables are cached per
  ``(bucket, backend, scheme, maxiter/chunk, program bytes)``
  (:func:`repro.core.isa.program_token`): word-identical programs share
  one executable, a different schedule costs one specialization.
* **generic** (``specialize=False``, the fallback) — the program is a
  *traced operand* dispatched word-at-a-time by ``lax.switch``; one
  compiled executable (cached per bucket/backend/scheme, the key
  deliberately excludes the program) runs paper-policy, min-traffic,
  plain-CG, or any other program of the same padded length with **no
  retrace** — the analogue of not re-running synthesis/place/route per
  problem.  Prefer it when programs are generated at runtime faster
  than they can be specialized (schedule search, fuzzing).

``tests/test_compile.py`` asserts bit-level agreement of both paths with
the phase engine and the cache economics of each; the front doors are
:func:`repro.core.batch.jpcg_solve_batched` (``engine="vm"``) and
:class:`repro.serve.SolverEngine`.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import _cached, _matvec_factory, _row_dot
from repro.core.isa import (BUF, CTRL_ALPHA, ITYPE_COMP, ITYPE_CTRL,
                            ITYPE_VCTRL, SREG, program_token)
from repro.core.precision import get_scheme

__all__ = ["BatchedVMState", "make_vm_runner", "make_vm_stepper",
           "vm_executable_stats", "vm_solve"]

_N_QUEUES = 8
_N_SREGS = 6
_N_BUFS = 6

#: COMP module id -> executor branch (0=spmv, 1=dot, 2=axpy, 3=div); the
#: VM's branch table is fixed, like the FPGA's module array.
_BRANCH_OF_MOD = (0, 1, 2, 2, 3, 1, 2, 1)


class BatchedVMState(NamedTuple):
    """Lane-batched VM state; every array's lane axis is G."""

    k: jax.Array         # global tick (int32 scalar)
    it: jax.Array        # int32[G] per-lane iteration counts
    mem: jax.Array       # [6, G, n] HBM vector buffers (x r p ap M b)
    queues: jax.Array    # [8, G, n] inter-module streams
    sregs: jax.Array     # [6, G] scalar registers (α β rz rr pap rz')
    active: jax.Array    # bool[G] live-lane mask
    trace: jax.Array     # [G, maxiter] rr per iteration, or [G, 0]


def _masked_trace(trace, k, keep, rr_new):
    """Record ``rr`` at column ``k`` for live lanes, or nothing at all
    when ``k`` is past the trace width.

    A with-trace state continued through :func:`make_vm_stepper` beyond
    its trace width drives ``k`` out of range.  The unguarded write only
    stayed a no-op because JAX silently *drops* out-of-bounds scatter
    updates (while the ``trace[:, k]`` gather feeding it clamps) —
    implicit semantics the solver must not lean on; the guard makes the
    out-of-range no-op explicit.
    """
    width = trace.shape[1]
    if not width:
        return trace
    safe_k = jnp.minimum(k, width - 1)
    ok = keep & (k < width)
    return trace.at[:, safe_k].set(jnp.where(ok, rr_new, trace[:, safe_k]))


# ------------------------------------------------------------ generic path
def _make_executor(matvec):
    """Per-instruction executor closed over the batched SpMV closure."""

    def exec_vctrl(w, st: BatchedVMState) -> BatchedVMState:
        buf, rd, wr, qa, qd = w[1], w[2], w[3], w[4], w[6]
        # rd: queue[qd] <- mem[buf] ; wr: mem[buf] <- queue[qa]
        q = jax.lax.cond(
            rd == 1,
            lambda: st.queues.at[qd].set(st.mem[buf]),
            lambda: st.queues)
        m = jax.lax.cond(
            wr == 1,
            lambda: st.mem.at[buf].set(st.queues[qa]),
            lambda: st.mem)
        return st._replace(mem=m, queues=q)

    def exec_comp(w, st: BatchedVMState) -> BatchedVMState:
        mod, neg, qa, qb, qd, sr = w[1], w[2], w[4], w[5], w[6], w[7]
        a = st.queues[qa]                       # [G, n]
        bq = st.queues[qb]
        s = st.sregs[sr]                        # [G]
        s = jnp.where(neg == 1, -s, s)

        def spmv():      # M1
            return st.queues.at[qd].set(matvec(a)), st.sregs

        def dot():       # M2 / M6 / M8 -> scalar register (row-wise)
            return st.queues, st.sregs.at[sr].set(_row_dot(a, bq))

        def axpy():      # M3 / M4 / M7: dst = a + s·b (per lane)
            return st.queues.at[qd].set(a + s[:, None] * bq), st.sregs

        def div():       # M5: dst = a / b  (Jacobi left-divide)
            return st.queues.at[qd].set(a / bq), st.sregs

        branch = jnp.array(_BRANCH_OF_MOD, jnp.int32)[mod]
        q, sregs = jax.lax.switch(branch, [spmv, dot, axpy, div])
        return st._replace(queues=q, sregs=sregs)

    def exec_ctrl(w, st: BatchedVMState) -> BatchedVMState:
        def alpha():     # α = rz / pap, per lane
            return st.sregs.at[SREG["alpha"]].set(
                st.sregs[SREG["rz"]] / st.sregs[SREG["pap"]])

        def beta():      # β = rz' / rz ; rz ← rz'
            s = st.sregs.at[SREG["beta"]].set(
                st.sregs[SREG["rz_new"]] / st.sregs[SREG["rz"]])
            return s.at[SREG["rz"]].set(st.sregs[SREG["rz_new"]])

        return st._replace(sregs=jax.lax.switch(w[1], [alpha, beta]))

    def exec_nop(w, st: BatchedVMState) -> BatchedVMState:
        return st

    def execute(w, st: BatchedVMState) -> BatchedVMState:
        return jax.lax.switch(
            w[0], [lambda: exec_vctrl(w, st), lambda: exec_comp(w, st),
                   lambda: exec_ctrl(w, st), lambda: exec_nop(w, st)])

    return execute


def vm_init(matvec, diag, b, x0, *, maxiter: int, with_trace: bool,
            tol) -> BatchedVMState:
    """Controller warm-up (paper Alg. 1 lines 1–5) — arithmetic identical
    to :func:`repro.core.batch._batched_init`, packed into VM buffers."""
    vd = b.dtype
    G = b.shape[0]
    r = b - matvec(x0)
    z = r / diag
    rz = _row_dot(r, z)
    rr = _row_dot(r, r)
    mem = jnp.stack([x0, r, z, jnp.zeros_like(r), diag, b])  # x r p ap M b
    sregs = jnp.zeros((_N_SREGS, G), vd)
    sregs = sregs.at[SREG["rz"]].set(rz).at[SREG["rr"]].set(rr)
    return BatchedVMState(
        k=jnp.zeros((), jnp.int32), it=jnp.zeros(G, jnp.int32), mem=mem,
        queues=jnp.zeros((_N_QUEUES,) + r.shape, vd), sregs=sregs,
        active=rr > tol,
        trace=jnp.zeros((G, maxiter if with_trace else 0), vd))


def _vm_body(program, matvec, tol, maxiter_vec=None):
    """One VM tick = run the program once = one JPCG iteration per lane.

    Frozen (converged) lanes flow through the arithmetic — dead compute
    on a SIMD device — but ``mem``/``sregs``/``queues`` writes are gated
    on ``active``, mirroring the masking semantics of
    :func:`repro.core.batch._batched_body` bit for bit.  (Queues included:
    a frozen lane's streams must not drift, or continuing a state through
    the serving stepper / bucket growth becomes nondeterministic.)
    """
    execute = _make_executor(matvec)

    def body(st: BatchedVMState) -> BatchedVMState:
        def step(pc, s):
            return execute(program[pc], s)

        nxt = jax.lax.fori_loop(0, program.shape[0], step, st)
        keep = st.active
        mem = jnp.where(keep[None, :, None], nxt.mem, st.mem)
        queues = jnp.where(keep[None, :, None], nxt.queues, st.queues)
        sregs = jnp.where(keep[None, :], nxt.sregs, st.sregs)
        it = st.it + keep.astype(jnp.int32)
        rr = sregs[SREG["rr"]]
        trace = _masked_trace(st.trace, st.k, keep, nxt.sregs[SREG["rr"]])
        active = keep & (rr > tol)
        if maxiter_vec is not None:
            active = active & (it < maxiter_vec)
        return BatchedVMState(k=st.k + 1, it=it, mem=mem,
                              queues=queues, sregs=sregs,
                              active=active, trace=trace)

    return body


# -------------------------------------------------------- specialized path
class _ProgramPlan(NamedTuple):
    """Trace-time analysis of a concrete program."""

    ops: Tuple[Tuple[int, ...], ...]   # decoded words (python ints)
    written_bufs: Tuple[int, ...]      # HBM buffers the program stores to
    accessed_queues: Tuple[int, ...]   # queues read or written (sorted)
    written_queues: Tuple[int, ...]    # queues written (subset of accessed)


def _analyze_program(program: np.ndarray) -> _ProgramPlan:
    """Decode a concrete program and compute the state it touches.

    Only touched buffers/queues enter the specialized loop's carried
    dataflow; untouched ones bypass the ``lax.while_loop`` entirely (they
    are reattached from the initial state afterwards).
    """
    ops = tuple(tuple(int(v) for v in w)
                for w in np.asarray(program, np.int32))
    wb, rq, wq = set(), set(), set()
    for w in ops:
        if w[0] == ITYPE_VCTRL:
            if w[2]:                     # rd: mem[buf] -> queue[qd]
                wq.add(w[6])
            if w[3]:                     # wr: queue[qa] -> mem[buf]
                rq.add(w[4])
                wb.add(w[1])
        elif w[0] == ITYPE_COMP:
            kind = _BRANCH_OF_MOD[w[1]]
            rq.add(w[4])                 # qa
            if kind != 0:                # dot / axpy / div read qb too
                rq.add(w[5])
            if kind != 1:                # spmv / axpy / div write qd
                wq.add(w[6])
    return _ProgramPlan(ops=ops, written_bufs=tuple(sorted(wb)),
                        accessed_queues=tuple(sorted(rq | wq)),
                        written_queues=tuple(sorted(wq)))


def _run_specialized(plan: _ProgramPlan, matvec, mem: List, queues: dict,
                     sregs):
    """Execute the program once, straight-line, with static indices.

    ``mem`` is a list of 6 ``[G, n]`` buffers, ``queues`` a dict
    ``{queue id: [G, n]}`` over the plan's accessed queues.  The
    arithmetic is word-for-word the generic executor's — same ops, same
    order, same dtypes — only the dispatch is resolved at trace time, so
    results are bit-identical to the generic path (and hence to the
    phases oracle).
    """
    mem = list(mem)
    queues = dict(queues)
    for w in plan.ops:
        if w[0] == ITYPE_VCTRL:
            buf, rd, wr, qa, qd = w[1], w[2], w[3], w[4], w[6]
            src_m = mem[buf]             # pre-instruction snapshots: a
            src_q = queues.get(qa)       # combined rd+wr word sees old state
            if wr:
                mem[buf] = src_q
            if rd:
                queues[qd] = src_m
        elif w[0] == ITYPE_COMP:
            mod, neg, qa, qb, qd, sr = w[1], w[2], w[4], w[5], w[6], w[7]
            kind = _BRANCH_OF_MOD[mod]
            a = queues[qa]
            if kind == 0:                # M1: SpMV
                queues[qd] = matvec(a)
            elif kind == 1:              # M2/M6/M8: row-wise dot -> sreg
                sregs = sregs.at[sr].set(_row_dot(a, queues[qb]))
            elif kind == 2:              # M3/M4/M7: dst = a ± s·b
                s = sregs[sr]
                if neg:
                    s = -s
                queues[qd] = a + s[:, None] * queues[qb]
            else:                        # M5: dst = a / b
                queues[qd] = a / queues[qb]
        elif w[0] == ITYPE_CTRL:
            if w[1] == CTRL_ALPHA:       # α = rz / pap
                sregs = sregs.at[SREG["alpha"]].set(
                    sregs[SREG["rz"]] / sregs[SREG["pap"]])
            else:                        # β = rz'/rz ; rz ← rz'
                new = sregs.at[SREG["beta"]].set(
                    sregs[SREG["rz_new"]] / sregs[SREG["rz"]])
                sregs = new.at[SREG["rz"]].set(sregs[SREG["rz_new"]])
        # NOP words vanish at trace time
    return mem, queues, sregs


class _SpecCarry(NamedTuple):
    """Loop-carried state of the specialized path: per-buffer / per-queue
    arrays instead of the monolithic files, so XLA sees straight-line
    dataflow through exactly the state the program touches."""

    k: jax.Array
    it: jax.Array
    mem: Tuple[jax.Array, ...]       # always all 6 buffers, [G, n] each
    queues: Tuple[jax.Array, ...]    # accessed queues only, [G, n] each
    sregs: jax.Array
    active: jax.Array
    trace: jax.Array


def _spec_carry_of(st: BatchedVMState, plan: _ProgramPlan) -> _SpecCarry:
    return _SpecCarry(
        k=st.k, it=st.it, mem=tuple(st.mem[i] for i in range(_N_BUFS)),
        queues=tuple(st.queues[q] for q in plan.accessed_queues),
        sregs=st.sregs, active=st.active, trace=st.trace)


def _state_of_spec_carry(c: _SpecCarry, st0: BatchedVMState,
                         plan: _ProgramPlan) -> BatchedVMState:
    """Reassemble a full :class:`BatchedVMState`; queues the program never
    touches keep their incoming (``st0``) contents."""
    queues = st0.queues
    for q, v in zip(plan.accessed_queues, c.queues):
        queues = queues.at[q].set(v)
    return BatchedVMState(k=c.k, it=c.it, mem=jnp.stack(c.mem),
                          queues=queues, sregs=c.sregs, active=c.active,
                          trace=c.trace)


def _spec_body(plan: _ProgramPlan, matvec, tol, maxiter_vec=None):
    """Specialized VM tick — identical masking semantics to
    :func:`_vm_body`, applied per touched buffer/queue."""
    wb = frozenset(plan.written_bufs)
    wq = frozenset(plan.written_queues)

    def body(c: _SpecCarry) -> _SpecCarry:
        q_in = dict(zip(plan.accessed_queues, c.queues))
        n_mem, n_q, n_sregs = _run_specialized(plan, matvec, list(c.mem),
                                               q_in, c.sregs)
        keep = c.active
        kv = keep[:, None]
        mem = tuple(jnp.where(kv, n_mem[i], c.mem[i]) if i in wb
                    else c.mem[i] for i in range(_N_BUFS))
        queues = tuple(jnp.where(kv, n_q[q], old) if q in wq else old
                       for q, old in zip(plan.accessed_queues, c.queues))
        sregs = jnp.where(keep[None, :], n_sregs, c.sregs)
        it = c.it + keep.astype(jnp.int32)
        rr = sregs[SREG["rr"]]
        trace = _masked_trace(c.trace, c.k, keep, n_sregs[SREG["rr"]])
        active = keep & (rr > tol)
        if maxiter_vec is not None:
            active = active & (it < maxiter_vec)
        return _SpecCarry(k=c.k + 1, it=it, mem=mem, queues=queues,
                          sregs=sregs, active=active, trace=trace)

    return body


# ------------------------------------------------------------ executables
def make_vm_runner(*, backend, scheme, maxiter, with_trace, block_rows,
                   col_tile, n_col_tiles, n_row_blocks, interpret=False,
                   program: Optional[np.ndarray] = None):
    """Build the jitted solve-to-completion VM runner for one bucket.

    With ``program=None`` (generic path) returns
    ``run(program, mat, diag, b, x0, tol) -> BatchedVMState`` — the
    program is a runtime operand and callers cache this runner keyed on
    the *bucket*, never on the program or VSR policy.

    With a concrete ``program`` array the runner is *specialized*: the
    program is unrolled at trace time and baked into the executable, the
    signature drops the operand —
    ``run(mat, diag, b, x0, tol) -> BatchedVMState`` — and callers must
    key their cache on :func:`repro.core.isa.program_token` of the
    program as well.
    """
    scheme = get_scheme(scheme)
    matvec_of = _matvec_factory(
        backend=backend, scheme=scheme, block_rows=block_rows,
        col_tile=col_tile, n_col_tiles=n_col_tiles,
        n_row_blocks=n_row_blocks, interpret=interpret)

    if program is None:
        @jax.jit
        def run(program, mat, diag, b, x0, tol):
            matvec = matvec_of(mat)
            st = vm_init(matvec, diag, b, x0, maxiter=maxiter,
                         with_trace=with_trace, tol=tol)
            body = _vm_body(program, matvec, tol)

            def cond(s):
                return (s.k < maxiter) & jnp.any(s.active)

            return jax.lax.while_loop(cond, body, st)

        return run

    plan = _analyze_program(program)

    @jax.jit
    def run_spec(mat, diag, b, x0, tol):
        matvec = matvec_of(mat)
        st0 = vm_init(matvec, diag, b, x0, maxiter=maxiter,
                      with_trace=with_trace, tol=tol)
        body = _spec_body(plan, matvec, tol)

        def cond(c):
            return (c.k < maxiter) & jnp.any(c.active)

        c = jax.lax.while_loop(cond, body, _spec_carry_of(st0, plan))
        return _state_of_spec_carry(c, st0, plan)

    return run_spec


def make_vm_stepper(*, backend, scheme, block_rows, col_tile, n_col_tiles,
                    n_row_blocks, chunk, interpret=False,
                    program: Optional[np.ndarray] = None):
    """Jitted bounded VM stepper for incremental serving (SolverEngine).

    Runs at most ``chunk`` program executions (= iterations) from a given
    state; per-lane budgets come in as ``maxiter_vec``.  Cached in the
    batch compile cache.

    * ``program=None`` — generic: cached per (backend, scheme, bucket,
      chunk), NOT per program, so every policy's program reuses one
      executable.  Returns
      ``step(program, mat, state, tol, maxiter_vec) -> state``.
    * concrete ``program`` — specialized: the program is baked in and the
      cache key gains its :func:`~repro.core.isa.program_token`, so
      word-identical programs share one executable and each distinct
      schedule costs one.  Returns
      ``step(mat, state, tol, maxiter_vec) -> state``.

    (No separate diag operand on either path — the preconditioner lives
    in ``mem[M]``.)
    """
    scheme = get_scheme(scheme)
    if program is None:
        key = ("vm_step", backend, scheme.name, block_rows, col_tile,
               n_col_tiles, n_row_blocks, chunk, interpret)

        def make():
            matvec_of = _matvec_factory(
                backend=backend, scheme=scheme, block_rows=block_rows,
                col_tile=col_tile, n_col_tiles=n_col_tiles,
                n_row_blocks=n_row_blocks, interpret=interpret)

            @jax.jit
            def step(program, mat, state, tol, maxiter_vec):
                matvec = matvec_of(mat)
                body = _vm_body(program, matvec, tol, maxiter_vec)
                start = state.k

                def cond(s):
                    return (s.k - start < chunk) & jnp.any(s.active)

                return jax.lax.while_loop(cond, body, state)

            return step

        return _cached(key, make)

    prog = np.asarray(program, np.int32)
    key = ("vm_step_spec", backend, scheme.name, block_rows, col_tile,
           n_col_tiles, n_row_blocks, chunk, interpret,
           program_token(prog))

    def make_spec():
        matvec_of = _matvec_factory(
            backend=backend, scheme=scheme, block_rows=block_rows,
            col_tile=col_tile, n_col_tiles=n_col_tiles,
            n_row_blocks=n_row_blocks, interpret=interpret)
        plan = _analyze_program(prog)

        @jax.jit
        def step(mat, state, tol, maxiter_vec):
            matvec = matvec_of(mat)
            body = _spec_body(plan, matvec, tol, maxiter_vec)
            start = state.k

            def cond(c):
                return (c.k - start < chunk) & jnp.any(c.active)

            c = jax.lax.while_loop(cond, body,
                                   _spec_carry_of(state, plan))
            return _state_of_spec_carry(c, state, plan)

        return step

    return _cached(key, make_spec)


def vm_executable_stats() -> dict:
    """VM executables in the batch compile cache + total traced shapes.

    ``specialized`` counts program-baked executables (cache keys
    ``vm_*_spec``, one per distinct program bytes per bucket);
    ``generic`` counts traced-operand executables (program excluded from
    the key).  ``traces`` counts jit cache entries across all of them:
    on the generic path, running a *different program* through an
    existing executable must not change it (the no-retrace acceptance
    check); only a new bucket shape, backend, scheme, or program *length*
    may.  On the specialized path new program bytes cost one entry by
    design.
    """
    from repro.core.batch import _CACHE
    fns, spec, gen = [], 0, 0
    for k, fn in _CACHE.items():
        if not (isinstance(k, tuple) and k and str(k[0]).startswith("vm_")):
            continue
        fns.append(fn)
        if str(k[0]).endswith("_spec"):
            spec += 1
        else:
            gen += 1
    return {"executables": len(fns), "specialized": spec, "generic": gen,
            "traces": int(sum(f._cache_size() for f in fns))}


# ---------------------------------------------------------------- public
def vm_solve(a, b=None, x0=None, *, program: np.ndarray, tol: float = 1e-12,
             maxiter: int = 20_000, scheme="mixed_v3",
             block_rows: int = 256, col_tile: int = 512,
             backend: str = "xla", specialize: bool = True,
             interpret: Optional[bool] = None) -> dict:
    """Solve Ax=b by executing ``program`` on the stream VM (batch of 1).

    Thin wrapper over :func:`repro.core.batch.jpcg_solve_batched` with
    ``engine="vm"`` — the single-system view of the one solver backend.
    ``specialize=False`` selects the generic traced-operand path.
    """
    from repro.core.batch import jpcg_solve_batched
    res = jpcg_solve_batched(
        [a], None if b is None else [b], None if x0 is None else [x0],
        tol=tol, maxiter=maxiter, scheme=scheme, backend=backend,
        engine="vm", program=program, specialize=specialize,
        block_rows=block_rows, col_tile=col_tile, interpret=interpret)[0]
    return {"x": res.x, "iterations": res.iterations, "rr": res.rr,
            "converged": res.converged}
