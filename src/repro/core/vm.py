"""Batched stream VM — executes stream-centric ISA programs (paper §3–§4)
for G independent systems at once, inside one compiled loop.

The VM models Callipepla's top architecture (paper Fig. 1), widened by a
lane dimension so it can serve the batched solver and the serving engine
directly — this is the *single solver backend*; the phase-fused loop in
:mod:`repro.core.phases` remains as its bit-exact oracle:

* **memory** — the HBM vector buffers (x, r, p, ap, M, b) as one
  ``[6, G, n]`` array: buffer id × lane × element;
* **queues** — the inter-module FIFOs, ``[8, G, n]``; a queue register
  holds one logical vector in flight per lane (fan-out is free, like the
  paper's VecCtrl element duplication);
* **computation modules** M1–M8 — M1 routes through the same batched
  SpMV closures as the phase engine
  (:func:`repro.core.batch._matvec_factory`: XLA flat-stream or Pallas
  ELLPACK), M2/M6/M8 are row-wise dot modules writing ``[G]`` scalar
  registers, M3/M4/M7 the axpy family, M5 the Jacobi left-divide;
* **global controller** — an outer ``lax.while_loop`` that runs the
  program once per iteration and terminates each lane on the fly at its
  own ``rr_g ≤ τ_g`` (paper Challenge 1, batched): every state write —
  ``mem``, ``sregs``, **and** ``queues`` — is gated on the lane's
  ``active`` flag exactly like :func:`repro.core.batch._batched_body`,
  so a converged lane's *entire* VM state freezes mid-batch while the
  survivors keep iterating.

Two execution paths share the VM's semantics:

* **specialized** (the production default) — when the program is a
  concrete ``np.ndarray`` at Python time (it always is for the front
  doors: :func:`repro.core.batch.jpcg_solve_batched` and
  :class:`repro.serve.SolverEngine` both obtain it from
  :func:`repro.core.compile.canonical_program`), the program is unrolled
  at *trace time* into straight-line jnp ops with static buffer/queue
  indices: no ``lax.switch``, no per-word ``lax.cond``, no dynamic
  gather/scatter over monolithic state.  The ``[8, G, n]`` queue file is
  decomposed into per-queue ``[G, n]`` arrays and the ``[6, G, n]``
  memory file into per-buffer arrays, so only state the program actually
  touches enters the loop-carried dataflow and XLA fuses a whole
  iteration the way :func:`repro.core.phases.vsr_iteration` fuses — the
  JAX analogue of the FPGA paying dispatch once at synthesis.
  Executables are cached per
  ``(bucket, backend, scheme, maxiter/chunk, program bytes)``
  (:func:`repro.core.isa.program_token`): word-identical programs share
  one executable, a different schedule costs one specialization.
* **generic** (``specialize=False``, the fallback) — the program is a
  *traced operand* dispatched word-at-a-time by ``lax.switch``; one
  compiled executable (cached per bucket/backend/scheme, the key
  deliberately excludes the program) runs paper-policy, min-traffic,
  plain-CG, or any other program of the same padded length with **no
  retrace** — the analogue of not re-running synthesis/place/route per
  problem.  Prefer it when programs are generated at runtime faster
  than they can be specialized (schedule search, fuzzing).

``tests/test_compile.py`` asserts bit-level agreement of both paths with
the phase engine and the cache economics of each; the front doors are
:func:`repro.core.batch.jpcg_solve_batched` (``engine="vm"``) and
:class:`repro.serve.SolverEngine`.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import _cached, _matvec_factory, _row_dot, _run_chunked
from repro.core.compile import executable_key
from repro.core.isa import (BUF, CTRL_ALPHA, ITYPE_COMP, ITYPE_CTRL,
                            ITYPE_VCTRL, SREG)
from repro.core.metrics import (advance_status, finalize_status,
                                initial_status, tick_health)
from repro.core.precision import get_scheme

__all__ = ["BatchedVMState", "make_vm_runner", "make_vm_stepper",
           "vm_executable_stats", "vm_solve"]

_N_QUEUES = 8
_N_SREGS = 6
_N_BUFS = 6

#: COMP module id -> executor branch (0=spmv, 1=dot, 2=axpy, 3=div); the
#: VM's branch table is fixed, like the FPGA's module array.
_BRANCH_OF_MOD = (0, 1, 2, 2, 3, 1, 2, 1)


class BatchedVMState(NamedTuple):
    """Lane-batched VM state; every array's lane axis is G."""

    k: jax.Array         # global tick (int32 scalar)
    it: jax.Array        # int32[G] per-lane iteration counts
    status: jax.Array    # int32[G] exit codes (repro.core.metrics.STATUS_*)
    mem: jax.Array       # [6, G, n] HBM vector buffers (x r p ap M b)
    queues: jax.Array    # [8, G, n] inter-module streams
    sregs: jax.Array     # [6, G] scalar registers (α β rz rr pap rz')
    active: jax.Array    # bool[G] live-lane mask
    trace: jax.Array     # [G, maxiter] rr per iteration, or [G, 0]


def _masked_trace(trace, k, keep, rr_new):
    """Record ``rr`` at column ``k`` for live lanes, or nothing at all
    when ``k`` is past the trace width.

    A with-trace state continued through :func:`make_vm_stepper` beyond
    its trace width drives ``k`` out of range.  The unguarded write only
    stayed a no-op because JAX silently *drops* out-of-bounds scatter
    updates (while the ``trace[:, k]`` gather feeding it clamps) —
    implicit semantics the solver must not lean on; the guard makes the
    out-of-range no-op explicit.
    """
    width = trace.shape[1]
    if not width:
        return trace
    safe_k = jnp.minimum(k, width - 1)
    ok = keep & (k < width)
    return trace.at[:, safe_k].set(jnp.where(ok, rr_new, trace[:, safe_k]))


# ------------------------------------------------------------ generic path
def _make_executor(matvec):
    """Per-instruction executor closed over the batched SpMV closure."""

    def exec_vctrl(w, st: BatchedVMState) -> BatchedVMState:
        buf, rd, wr, qa, qd = w[1], w[2], w[3], w[4], w[6]
        # rd: queue[qd] <- mem[buf] ; wr: mem[buf] <- queue[qa]
        q = jax.lax.cond(
            rd == 1,
            lambda: st.queues.at[qd].set(st.mem[buf]),
            lambda: st.queues)
        m = jax.lax.cond(
            wr == 1,
            lambda: st.mem.at[buf].set(st.queues[qa]),
            lambda: st.mem)
        return st._replace(mem=m, queues=q)

    def exec_comp(w, st: BatchedVMState) -> BatchedVMState:
        mod, neg, qa, qb, qd, sr = w[1], w[2], w[4], w[5], w[6], w[7]
        a = st.queues[qa]                       # [G, n]
        bq = st.queues[qb]
        s = st.sregs[sr]                        # [G]
        s = jnp.where(neg == 1, -s, s)

        def spmv():      # M1
            return st.queues.at[qd].set(matvec(a)), st.sregs

        def dot():       # M2 / M6 / M8 -> scalar register (row-wise)
            return st.queues, st.sregs.at[sr].set(_row_dot(a, bq))

        def axpy():      # M3 / M4 / M7: dst = a + s·b (per lane)
            return st.queues.at[qd].set(a + s[:, None] * bq), st.sregs

        def div():       # M5: dst = a / b  (Jacobi left-divide)
            return st.queues.at[qd].set(a / bq), st.sregs

        branch = jnp.array(_BRANCH_OF_MOD, jnp.int32)[mod]
        q, sregs = jax.lax.switch(branch, [spmv, dot, axpy, div])
        return st._replace(queues=q, sregs=sregs)

    def exec_ctrl(w, st: BatchedVMState) -> BatchedVMState:
        def alpha():     # α = rz / pap, per lane
            return st.sregs.at[SREG["alpha"]].set(
                st.sregs[SREG["rz"]] / st.sregs[SREG["pap"]])

        def beta():      # β = rz' / rz ; rz ← rz'
            s = st.sregs.at[SREG["beta"]].set(
                st.sregs[SREG["rz_new"]] / st.sregs[SREG["rz"]])
            return s.at[SREG["rz"]].set(st.sregs[SREG["rz_new"]])

        return st._replace(sregs=jax.lax.switch(w[1], [alpha, beta]))

    def exec_nop(w, st: BatchedVMState) -> BatchedVMState:
        return st

    def execute(w, st: BatchedVMState) -> BatchedVMState:
        return jax.lax.switch(
            w[0], [lambda: exec_vctrl(w, st), lambda: exec_comp(w, st),
                   lambda: exec_ctrl(w, st), lambda: exec_nop(w, st)])

    return execute


def vm_init(matvec, diag, b, x0, *, maxiter: int, with_trace: bool,
            tol, detect: bool = True) -> BatchedVMState:
    """Controller warm-up (paper Alg. 1 lines 1–5) — arithmetic identical
    to :func:`repro.core.batch._batched_init`, packed into VM buffers."""
    vd = b.dtype
    G = b.shape[0]
    r = b - matvec(x0)
    z = r / diag
    rz = _row_dot(r, z)
    rr = _row_dot(r, r)
    mem = jnp.stack([x0, r, z, jnp.zeros_like(r), diag, b])  # x r p ap M b
    sregs = jnp.zeros((_N_SREGS, G), vd)
    sregs = sregs.at[SREG["rz"]].set(rz).at[SREG["rr"]].set(rr)
    return BatchedVMState(
        k=jnp.zeros((), jnp.int32), it=jnp.zeros(G, jnp.int32),
        status=initial_status(rr, tol, detect=detect), mem=mem,
        queues=jnp.zeros((_N_QUEUES,) + r.shape, vd), sregs=sregs,
        active=rr > tol,
        trace=jnp.zeros((G, maxiter if with_trace else 0), vd))


def _vm_body(program, matvec, tol, maxiter_vec=None, *, bound=None,
             write_trace=True, detect=True):
    """One VM tick = run the program once = one JPCG iteration per lane.

    Frozen (converged) lanes flow through the arithmetic — dead compute
    on a SIMD device — but ``mem``/``sregs``/``queues`` writes are gated
    on ``active``, mirroring the masking semantics of
    :func:`repro.core.batch._batched_body` bit for bit.  (Queues included:
    a frozen lane's streams must not drift, or continuing a state through
    the serving stepper / bucket growth becomes nondeterministic.)

    ``bound``/``write_trace`` mirror :func:`repro.core.batch._batched_body`:
    the tick self-gates so it can run inside an iteration chunk (the
    whole tick is a no-op once every lane converged or ``k`` reached
    ``bound``), and the chunked with-trace runner hoists the trace
    scatter out of the tick.

    ``detect`` reads the tick's *candidate* scalar registers (``pap`` /
    ``alpha`` / ``beta`` / ``rr`` — every canonical program writes them;
    a custom program that doesn't must run with ``detect=False``) through
    :func:`repro.core.metrics.tick_health`: a lane that trips it discards
    the whole tick — ``mem``/``queues``/``sregs`` untouched, ``it`` not
    advanced — and latches its breakdown ``status``.  Masking semantics
    stay word-for-word identical to :func:`repro.core.batch._batched_body`.
    """
    execute = _make_executor(matvec)

    def body(st: BatchedVMState) -> BatchedVMState:
        def step(pc, s):
            return execute(program[pc], s)

        nxt = jax.lax.fori_loop(0, program.shape[0], step, st)
        go = jnp.any(st.active)
        if bound is not None:
            go = go & (st.k < bound)
        keep = st.active & go
        rr_cand = nxt.sregs[SREG["rr"]]
        upd, bd_i, bd_n = tick_health(
            keep, nxt.sregs[SREG["pap"]], nxt.sregs[SREG["alpha"]],
            nxt.sregs[SREG["beta"]], rr_cand, detect=detect)
        mem = jnp.where(upd[None, :, None], nxt.mem, st.mem)
        queues = jnp.where(upd[None, :, None], nxt.queues, st.queues)
        sregs = jnp.where(upd[None, :], nxt.sregs, st.sregs)
        it = st.it + upd.astype(jnp.int32)
        rr = sregs[SREG["rr"]]
        if write_trace:
            trace = _masked_trace(st.trace, st.k, upd, rr_cand)
        else:
            trace = st.trace
        live = rr > tol
        if maxiter_vec is not None:
            live = live & (it < maxiter_vec)
        if detect:
            live = live & ~(bd_i | bd_n)
        status = advance_status(st.status, upd=upd, bd_indef=bd_i,
                                bd_nonf=bd_n, rr_new=rr_cand, tol=tol,
                                it=it, maxiter_vec=maxiter_vec)
        active = jnp.where(keep, live, st.active)
        return BatchedVMState(k=st.k + go.astype(jnp.int32), it=it,
                              status=status, mem=mem, queues=queues,
                              sregs=sregs, active=active, trace=trace)

    return body


# -------------------------------------------------------- specialized path
class _ProgramPlan(NamedTuple):
    """Trace-time analysis of a concrete program.

    ``carried_bufs`` / ``live_queues`` define the *loop-carried* state —
    everything else provably cannot influence (or be influenced by) the
    iteration and bypasses the ``lax.while_loop`` entirely:

    * a buffer the program neither loads nor stores (e.g. ``b`` after
      warm-up) is dead weight — it rides through from the initial state;
    * a queue whose first access within one program execution is a
      *write* is **phase-local**: the program re-derives it from memory
      every iteration, so carrying its value between iterations moves
      ``[G, n]`` data for nothing.  Only queues that are read before
      written (live-in) must be carried.  Compiled canonical programs
      have *zero* live-in queues — every consumed stream is loaded by a
      VecCtrl ``rd`` or produced by an earlier module in the same
      execution — so the steady-state carry is exactly the paper's
      loop-carried vectors plus scalars.
    """

    ops: Tuple[Tuple[int, ...], ...]   # decoded words (python ints)
    read_bufs: Tuple[int, ...]         # HBM buffers the program loads
    written_bufs: Tuple[int, ...]      # HBM buffers the program stores to
    carried_bufs: Tuple[int, ...]      # read ∪ written (the mem carry)
    live_queues: Tuple[int, ...]       # queues read before first write
    written_queues: Tuple[int, ...]    # queues written


def _analyze_program(program: np.ndarray) -> _ProgramPlan:
    """Decode a concrete program and compute the state it touches.

    Only touched buffers and *live-in* queues enter the specialized
    loop's carried dataflow (see :class:`_ProgramPlan`); the rest bypass
    the ``lax.while_loop`` entirely and are reattached from the initial
    state afterwards.
    """
    ops = tuple(tuple(int(v) for v in w)
                for w in np.asarray(program, np.int32))
    rb, wb, wq, live = set(), set(), set(), set()

    def read_queue(q):
        if q not in wq:                  # first access is a read: live-in
            live.add(q)

    for w in ops:
        if w[0] == ITYPE_VCTRL:
            # combined rd+wr words see pre-instruction state (snapshot
            # semantics, same as _run_specialized): account the queue
            # read before the queue write.
            if w[3]:                     # wr: queue[qa] -> mem[buf]
                read_queue(w[4])
                wb.add(w[1])
            if w[2]:                     # rd: mem[buf] -> queue[qd]
                rb.add(w[1])
                wq.add(w[6])
        elif w[0] == ITYPE_COMP:
            kind = _BRANCH_OF_MOD[w[1]]
            read_queue(w[4])             # qa
            if kind != 0:                # dot / axpy / div read qb too
                read_queue(w[5])
            if kind != 1:                # spmv / axpy / div write qd
                wq.add(w[6])
    return _ProgramPlan(ops=ops, read_bufs=tuple(sorted(rb)),
                        written_bufs=tuple(sorted(wb)),
                        carried_bufs=tuple(sorted(rb | wb)),
                        live_queues=tuple(sorted(live)),
                        written_queues=tuple(sorted(wq)))


def _run_specialized(plan: _ProgramPlan, matvec, mem: dict, queues: dict,
                     sregs):
    """Execute the program once, straight-line, with static indices.

    ``mem`` is a dict ``{buffer id: [G, n]}`` over the plan's carried
    buffers, ``queues`` a dict ``{queue id: [G, n]}`` over its live-in
    queues (phase-local queues materialize on first write).  The
    arithmetic is word-for-word the generic executor's — same ops, same
    order, same dtypes — only the dispatch is resolved at trace time, so
    results are bit-identical to the generic path (and hence to the
    phases oracle).
    """
    mem = dict(mem)
    queues = dict(queues)
    for w in plan.ops:
        if w[0] == ITYPE_VCTRL:
            buf, rd, wr, qa, qd = w[1], w[2], w[3], w[4], w[6]
            src_m = mem[buf]             # pre-instruction snapshots: a
            src_q = queues.get(qa)       # combined rd+wr word sees old state
            if wr:
                mem[buf] = src_q
            if rd:
                queues[qd] = src_m
        elif w[0] == ITYPE_COMP:
            mod, neg, qa, qb, qd, sr = w[1], w[2], w[4], w[5], w[6], w[7]
            kind = _BRANCH_OF_MOD[mod]
            a = queues[qa]
            if kind == 0:                # M1: SpMV
                queues[qd] = matvec(a)
            elif kind == 1:              # M2/M6/M8: row-wise dot -> sreg
                sregs = sregs.at[sr].set(_row_dot(a, queues[qb]))
            elif kind == 2:              # M3/M4/M7: dst = a ± s·b
                s = sregs[sr]
                if neg:
                    s = -s
                queues[qd] = a + s[:, None] * queues[qb]
            else:                        # M5: dst = a / b
                queues[qd] = a / queues[qb]
        elif w[0] == ITYPE_CTRL:
            if w[1] == CTRL_ALPHA:       # α = rz / pap
                sregs = sregs.at[SREG["alpha"]].set(
                    sregs[SREG["rz"]] / sregs[SREG["pap"]])
            else:                        # β = rz'/rz ; rz ← rz'
                new = sregs.at[SREG["beta"]].set(
                    sregs[SREG["rz_new"]] / sregs[SREG["rz"]])
                sregs = new.at[SREG["rz"]].set(sregs[SREG["rz_new"]])
        # NOP words vanish at trace time
    return mem, queues, sregs


class _SpecCarry(NamedTuple):
    """Loop-carried state of the specialized path: per-buffer / per-queue
    arrays instead of the monolithic files, so XLA sees straight-line
    dataflow through exactly the state the program *proves* it needs —
    carried buffers and live-in queues only (:class:`_ProgramPlan`);
    dead buffers and phase-local queues never enter the loop."""

    k: jax.Array
    it: jax.Array
    status: jax.Array
    mem: Tuple[jax.Array, ...]       # carried buffers only, [G, n] each
    queues: Tuple[jax.Array, ...]    # live-in queues only, [G, n] each
    sregs: jax.Array
    active: jax.Array
    trace: jax.Array


def _spec_carry_of(st: BatchedVMState, plan: _ProgramPlan) -> _SpecCarry:
    return _SpecCarry(
        k=st.k, it=st.it, status=st.status,
        mem=tuple(st.mem[i] for i in plan.carried_bufs),
        queues=tuple(st.queues[q] for q in plan.live_queues),
        sregs=st.sregs, active=st.active, trace=st.trace)


def _state_of_spec_carry(c: _SpecCarry, st0: BatchedVMState,
                         plan: _ProgramPlan) -> BatchedVMState:
    """Reassemble a full :class:`BatchedVMState`.

    State the loop did not carry passes through from ``st0``: buffers
    the program never touches, and — since the live-in analysis — every
    *phase-local* queue (written before read).  A phase-local queue's
    contents are an artifact of the last execution, re-derived from
    memory on the next; preserving the incoming value is the documented
    pass-through contract (asserted by the serving-engine tests).
    """
    mem = st0.mem
    for i, v in zip(plan.carried_bufs, c.mem):
        mem = mem.at[i].set(v)
    queues = st0.queues
    for q, v in zip(plan.live_queues, c.queues):
        queues = queues.at[q].set(v)
    return BatchedVMState(k=c.k, it=c.it, status=c.status, mem=mem,
                          queues=queues, sregs=c.sregs, active=c.active,
                          trace=c.trace)


def _spec_body(plan: _ProgramPlan, matvec, tol, maxiter_vec=None, *,
               bound=None, write_trace=True, detect=True):
    """Specialized VM tick — identical masking semantics to
    :func:`_vm_body`, applied per carried buffer/queue; ``bound`` makes
    the tick self-gating for chunked execution (see
    :func:`repro.core.batch._batched_body`); ``detect`` classifies the
    same candidate scalar registers through the same
    :func:`repro.core.metrics.tick_health`, so the two VM paths stay
    guaranteed-identical with detection on or off."""
    wb = frozenset(plan.written_bufs)
    wq = frozenset(plan.written_queues)

    def body(c: _SpecCarry) -> _SpecCarry:
        m_in = dict(zip(plan.carried_bufs, c.mem))
        q_in = dict(zip(plan.live_queues, c.queues))
        n_mem, n_q, n_sregs = _run_specialized(plan, matvec, m_in, q_in,
                                               c.sregs)
        go = jnp.any(c.active)
        if bound is not None:
            go = go & (c.k < bound)
        keep = c.active & go
        rr_cand = n_sregs[SREG["rr"]]
        upd, bd_i, bd_n = tick_health(
            keep, n_sregs[SREG["pap"]], n_sregs[SREG["alpha"]],
            n_sregs[SREG["beta"]], rr_cand, detect=detect)
        kv = upd[:, None]
        mem = tuple(jnp.where(kv, n_mem[i], old) if i in wb else old
                    for i, old in zip(plan.carried_bufs, c.mem))
        queues = tuple(jnp.where(kv, n_q[q], old) if q in wq else old
                       for q, old in zip(plan.live_queues, c.queues))
        sregs = jnp.where(upd[None, :], n_sregs, c.sregs)
        it = c.it + upd.astype(jnp.int32)
        rr = sregs[SREG["rr"]]
        if write_trace:
            trace = _masked_trace(c.trace, c.k, upd, rr_cand)
        else:
            trace = c.trace
        live = rr > tol
        if maxiter_vec is not None:
            live = live & (it < maxiter_vec)
        if detect:
            live = live & ~(bd_i | bd_n)
        status = advance_status(c.status, upd=upd, bd_indef=bd_i,
                                bd_nonf=bd_n, rr_new=rr_cand, tol=tol,
                                it=it, maxiter_vec=maxiter_vec)
        active = jnp.where(keep, live, c.active)
        return _SpecCarry(k=c.k + go.astype(jnp.int32), it=it,
                          status=status, mem=mem, queues=queues,
                          sregs=sregs, active=active, trace=trace)

    return body


# ------------------------------------------------------------ executables
def make_vm_runner(*, backend, scheme, maxiter, with_trace, layout=None,
                   groups=None, block_rows=None, col_tile=None,
                   n_col_tiles=None, steps_per_sync: int = 8,
                   donate: bool = False, detect: bool = True,
                   interpret=False, mesh=None,
                   program: Optional[np.ndarray] = None):
    """Build the jitted solve-to-completion VM runner for one bucket.

    With ``program=None`` (generic path) returns
    ``run(program, mat, diag, b, x0, tol) -> BatchedVMState`` — the
    program is a runtime operand and callers cache this runner keyed on
    the *bucket*, never on the program or VSR policy.

    With a concrete ``program`` array the runner is *specialized*: the
    program is unrolled at trace time and baked into the executable, the
    signature drops the operand —
    ``run(mat, diag, b, x0, tol) -> BatchedVMState`` — and callers must
    key their cache on :func:`repro.core.isa.program_token` of the
    program as well.

    ``steps_per_sync`` = VM ticks per termination-predicate sync
    (bit-identical for any value — ticks self-gate; see
    :func:`repro.core.batch._run_chunked`); it and ``donate`` must join
    the caller's cache key (:func:`repro.core.compile.executable_key`).
    ``donate=True`` donates the ``b``/``x0`` operands into the warm-up —
    only safe when the caller constructs them fresh per call.
    ``detect`` arms breakdown detection (static — joins the caller's
    cache key); leftover ``RUNNING`` statuses finalize to ``MAXITER``
    before the state is returned.  ``mesh`` shards the operands' lane
    axis over a device mesh before the jitted call
    (:mod:`repro.core.shard`; the caller's cache key must include the
    mesh signature) — lanes are independent, so results stay
    bit-identical to the single-device path.
    """
    scheme = get_scheme(scheme)
    matvec_of = _matvec_factory(
        backend=backend, scheme=scheme, layout=layout, groups=groups,
        block_rows=block_rows, col_tile=col_tile,
        n_col_tiles=n_col_tiles, interpret=interpret)
    hoist_trace = with_trace and steps_per_sync > 1
    rr_of = lambda s: s.sregs[SREG["rr"]]  # noqa: E731

    if program is None:
        def run(program, mat, diag, b, x0, tol):
            matvec = matvec_of(mat)
            st = vm_init(matvec, diag, b, x0, maxiter=maxiter,
                         with_trace=with_trace, tol=tol, detect=detect)
            tick = _vm_body(program, matvec, tol, bound=maxiter,
                            write_trace=not hoist_trace, detect=detect)

            def cond(s):
                return (s.k < maxiter) & jnp.any(s.active)

            out = _run_chunked(cond, tick, st, steps=steps_per_sync,
                               with_trace=with_trace, maxiter=maxiter,
                               rr_of=rr_of)
            return out._replace(status=finalize_status(out.status))

        fn = jax.jit(run, donate_argnums=(3, 4) if donate else ())
        if mesh is None:
            return fn
        from repro.core.shard import place_lanes, place_replicated

        def run_sharded(program, mat, diag, b, x0, tol):
            return fn(place_replicated(mesh, program),
                      place_lanes(mesh, mat), place_lanes(mesh, diag),
                      place_lanes(mesh, b), place_lanes(mesh, x0),
                      place_lanes(mesh, tol))

        run_sharded._cache_size = fn._cache_size   # vm_executable_stats
        return run_sharded

    plan = _analyze_program(program)

    def run_spec(mat, diag, b, x0, tol):
        matvec = matvec_of(mat)
        st0 = vm_init(matvec, diag, b, x0, maxiter=maxiter,
                      with_trace=with_trace, tol=tol, detect=detect)
        tick = _spec_body(plan, matvec, tol, bound=maxiter,
                          write_trace=not hoist_trace, detect=detect)

        def cond(c):
            return (c.k < maxiter) & jnp.any(c.active)

        c = _run_chunked(cond, tick, _spec_carry_of(st0, plan),
                         steps=steps_per_sync, with_trace=with_trace,
                         maxiter=maxiter, rr_of=rr_of)
        out = _state_of_spec_carry(c, st0, plan)
        return out._replace(status=finalize_status(out.status))

    fn_spec = jax.jit(run_spec, donate_argnums=(2, 3) if donate else ())
    if mesh is None:
        return fn_spec
    from repro.core.shard import place_lanes

    def run_spec_sharded(mat, diag, b, x0, tol):
        return fn_spec(place_lanes(mesh, mat), place_lanes(mesh, diag),
                       place_lanes(mesh, b), place_lanes(mesh, x0),
                       place_lanes(mesh, tol))

    run_spec_sharded._cache_size = fn_spec._cache_size
    return run_spec_sharded


def make_vm_stepper(*, backend, scheme, bucket, chunk, layout=None,
                    groups=None, index_bytes=None, block_rows=None,
                    col_tile=None, n_col_tiles=None,
                    steps_per_sync: int = 8, donate: bool = False,
                    detect: bool = True, interpret=False, mesh=None,
                    program: Optional[np.ndarray] = None):
    """Jitted bounded VM stepper for incremental serving (SolverEngine).

    Runs at most ``chunk`` program executions (= iterations) from a given
    state; per-lane budgets come in as ``maxiter_vec``.  Cached in the
    batch compile cache; ``bucket`` is the padded-operand dims tuple that
    keys the cache (row-ELL ``(n_pad, W)`` on XLA).

    * ``program=None`` — generic: cached per (backend, scheme, bucket,
      chunk), NOT per program, so every policy's program reuses one
      executable.  Returns
      ``step(program, mat, state, tol, maxiter_vec) -> state``.
    * concrete ``program`` — specialized: the program is baked in and the
      cache key gains its :func:`~repro.core.isa.program_token`, so
      word-identical programs share one executable and each distinct
      schedule costs one.  Returns
      ``step(mat, state, tol, maxiter_vec) -> state``.

    ``steps_per_sync`` ticks run per termination sync (capped at
    ``chunk``; bit-identical — each tick self-gates on the remaining
    budget, so ``k`` never overshoots ``chunk``).  ``donate=True``
    donates the *state* operand: the caller must not touch the passed
    state again (the serving engine's linear state hand-off; anything it
    retains across a step — harvested results — must be materialized
    first).  (No separate diag operand on either path — the
    preconditioner lives in ``mem[M]``.)

    ``mesh`` shards the lane axis over a device mesh
    (:mod:`repro.core.shard`): operands and state are re-placed with
    ``NamedSharding`` before every step (a no-op once they carry the
    target layout), and the mesh signature joins the cache key so the
    sharded stepper never collides with the single-device one.
    """
    scheme = get_scheme(scheme)
    inner = max(1, min(int(steps_per_sync), int(chunk)))
    key_kw = dict(backend=backend, scheme=scheme.name, bucket=bucket,
                  layout=layout, index_bytes=index_bytes, chunk=chunk,
                  steps_per_sync=inner, donate=donate, detect=detect,
                  interpret=interpret, mesh=mesh)

    def chunked(cond, tick, st):
        if inner <= 1:
            return jax.lax.while_loop(cond, tick, st)
        return jax.lax.while_loop(
            cond,
            lambda s: jax.lax.fori_loop(0, inner, lambda _, ss: tick(ss),
                                        s),
            st)

    if program is None:
        key = executable_key("vm_step", **key_kw)

        def make():
            matvec_of = _matvec_factory(
                backend=backend, scheme=scheme, layout=layout,
                groups=groups, block_rows=block_rows, col_tile=col_tile,
                n_col_tiles=n_col_tiles, interpret=interpret)

            def step(program, mat, state, tol, maxiter_vec):
                matvec = matvec_of(mat)
                start = state.k
                tick = _vm_body(program, matvec, tol, maxiter_vec,
                                bound=start + chunk, detect=detect)

                def cond(s):
                    return (s.k - start < chunk) & jnp.any(s.active)

                return chunked(cond, tick, state)

            fn = jax.jit(step, donate_argnums=(2,) if donate else ())
            if mesh is None:
                return fn
            from repro.core.shard import (place_lanes, place_replicated,
                                          place_vm_state)

            def step_sharded(program, mat, state, tol, maxiter_vec):
                return fn(place_replicated(mesh, program),
                          place_lanes(mesh, mat),
                          place_vm_state(mesh, state),
                          place_lanes(mesh, tol),
                          place_lanes(mesh, maxiter_vec))

            step_sharded._cache_size = fn._cache_size
            return step_sharded

        return _cached(key, make)

    prog = np.asarray(program, np.int32)
    key = executable_key("vm_step_spec", program=prog, **key_kw)

    def make_spec():
        matvec_of = _matvec_factory(
            backend=backend, scheme=scheme, layout=layout, groups=groups,
            block_rows=block_rows, col_tile=col_tile,
            n_col_tiles=n_col_tiles, interpret=interpret)
        plan = _analyze_program(prog)

        def step(mat, state, tol, maxiter_vec):
            matvec = matvec_of(mat)
            start = state.k
            tick = _spec_body(plan, matvec, tol, maxiter_vec,
                              bound=start + chunk, detect=detect)

            def cond(c):
                return (c.k - start < chunk) & jnp.any(c.active)

            c = chunked(cond, tick, _spec_carry_of(state, plan))
            return _state_of_spec_carry(c, state, plan)

        fn = jax.jit(step, donate_argnums=(1,) if donate else ())
        if mesh is None:
            return fn
        from repro.core.shard import place_lanes, place_vm_state

        def step_sharded(mat, state, tol, maxiter_vec):
            return fn(place_lanes(mesh, mat),
                      place_vm_state(mesh, state),
                      place_lanes(mesh, tol),
                      place_lanes(mesh, maxiter_vec))

        step_sharded._cache_size = fn._cache_size
        return step_sharded

    return _cached(key, make_spec)


def vm_executable_stats() -> dict:
    """VM executables in the batch compile cache + total traced shapes.

    ``specialized`` counts program-baked executables (cache keys
    ``vm_*_spec``, one per distinct program bytes per bucket);
    ``generic`` counts traced-operand executables (program excluded from
    the key).  ``traces`` counts jit cache entries across all of them:
    on the generic path, running a *different program* through an
    existing executable must not change it (the no-retrace acceptance
    check); only a new bucket shape, backend, scheme, or program *length*
    may.  On the specialized path new program bytes cost one entry by
    design.
    """
    from repro.core.batch import _CACHE
    fns, spec, gen = [], 0, 0
    for k, fn in _CACHE.items():
        if not (isinstance(k, tuple) and k and str(k[0]).startswith("vm_")):
            continue
        fns.append(fn)
        if str(k[0]).endswith("_spec"):
            spec += 1
        else:
            gen += 1
    return {"executables": len(fns), "specialized": spec, "generic": gen,
            "traces": int(sum(f._cache_size() for f in fns))}


# ---------------------------------------------------------------- public
def vm_solve(a, b=None, x0=None, *, program: np.ndarray, tol: float = 1e-12,
             maxiter: int = 20_000, scheme="mixed_v3",
             block_rows: int = 256, col_tile: int = 512,
             backend: str = "xla", specialize: bool = True,
             interpret: Optional[bool] = None) -> dict:
    """Solve Ax=b by executing ``program`` on the stream VM (batch of 1).

    Thin wrapper over :func:`repro.core.batch.jpcg_solve_batched` with
    ``engine="vm"`` — the single-system view of the one solver backend.
    ``specialize=False`` selects the generic traced-operand path.
    """
    from repro.core.batch import jpcg_solve_batched
    res = jpcg_solve_batched(
        [a], None if b is None else [b], None if x0 is None else [x0],
        tol=tol, maxiter=maxiter, scheme=scheme, backend=backend,
        engine="vm", program=program, specialize=specialize,
        block_rows=block_rows, col_tile=col_tile, interpret=interpret)[0]
    return {"x": res.x, "iterations": res.iterations, "rr": res.rr,
            "converged": res.converged}
