"""Matrix-free Gauss–Newton operators — the solver ↔ LM-training bridge.

The CGGN (Hessian-free) optimizer solves ``(G + λI) δ = −g`` each step,
where ``G`` is the generalized Gauss–Newton matrix of the loss.  ``G`` is
SPD, never materialized: ``G·v = Jᵀ (H_L (J v))`` via a jvp through the
model and a vjp back (standard Pearlmutter trick).  That makes it exactly
the operator class Callipepla's JPCG consumes — with the paper's
mixed-precision scheme mapped one tier down (DESIGN.md §2): the *matvec*
runs at the model's compute dtype (bf16/fp32 = "the matrix is stored low"),
while the CG iterate vectors stay fp32 (= "vectors stay high").

The Jacobi preconditioner is the diagonal of ``G + λI``, estimated with
Hutchinson probes: ``diag(G) ≈ E[e ⊙ (G e)]`` over Rademacher ``e``.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_ggn_matvec", "estimate_jacobi_diag", "flatten_like"]


def flatten_like(tree):
    """Ravel a pytree to a single vector + unravel fn (pure-jax, no flax)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(jnp.size(l)) for l in leaves]
    dtype = jnp.result_type(*[l.dtype for l in leaves]) if leaves else jnp.float32

    def ravel(t):
        ls = jax.tree_util.tree_leaves(t)
        return jnp.concatenate([jnp.ravel(l).astype(dtype) for l in ls]) \
            if ls else jnp.zeros(0, dtype)

    def unravel(v):
        out, ofs = [], 0
        for sh, sz, leaf in zip(shapes, sizes, leaves):
            out.append(v[ofs: ofs + sz].reshape(sh).astype(leaf.dtype))
            ofs += sz
        return jax.tree_util.tree_unflatten(treedef, out)

    return ravel(tree), ravel, unravel


def make_ggn_matvec(loss_logits_fn: Callable, logits_fn: Callable, params,
                    damping: float = 1e-3) -> Tuple[Callable, int]:
    """Build v ↦ (G + λI)·v  for  G = Jᵀ H_L J  (flattened param space).

    ``logits_fn(params) -> logits`` is the model on a fixed batch;
    ``loss_logits_fn(logits) -> scalar`` is the loss as a function of the
    logits (so H_L is the small per-logit Hessian, PSD for CE/MSE).
    """
    theta0, _, unravel = flatten_like(params)
    n = int(theta0.shape[0])

    def matvec(v: jax.Array) -> jax.Array:
        vt = unravel(v)
        # J v  (forward-mode through the model)
        logits, jv = jax.jvp(logits_fn, (params,), (vt,))
        # H_L (J v) via double-grad of the loss wrt logits
        def g(lg):
            return jax.grad(loss_logits_fn)(lg)
        _, hjv = jax.jvp(g, (logits,), (jv,))
        # Jᵀ (H_L J v)  (reverse-mode back)
        _, vjp = jax.vjp(logits_fn, params)
        (gv,) = vjp(hjv)
        flat, _, _ = flatten_like(gv)
        return flat + damping * v.astype(flat.dtype)

    return matvec, n


def estimate_jacobi_diag(matvec: Callable, n: int, key: jax.Array,
                         probes: int = 8, damping: float = 1e-3,
                         dtype=jnp.float32) -> jax.Array:
    """Hutchinson estimate of diag(G) + λ, clipped positive (SPD guard)."""
    def one(k):
        e = jax.random.rademacher(k, (n,), dtype=dtype)
        return e * matvec(e)

    est = jnp.mean(jax.vmap(one)(jax.random.split(key, probes)), axis=0)
    return jnp.maximum(est, damping)
