"""Vector streaming reuse (VSR) analysis — paper §5, computed, not hand-wired.

The paper partitions the JPCG loop body into three phases by *scalar
dependency* analysis (Fig. 5): a dot product consumes a whole vector before
its scalar exists, so any module needing that scalar starts a new phase;
within a phase, vectors flow module-to-module through on-chip streams (FPGA
FIFOs; VMEM-resident tiles inside one fused kernel on TPU) and touch HBM at
most once each.

This module reifies the analysis.  The JPCG dataflow graph is declared as
data (``JPCG_MODULES``, loop-carried outputs primed: ``r'``/``p'``/``x'``
are next-iteration values), and :func:`schedule` computes

1. earliest phase per module from the scalar-barrier closure,
2. a *sink* pass that moves modules without intra-iteration consumers to
   their latest legal phase (this reproduces the paper's placement of M3 in
   phase 3, where it shares the ``p`` stream with M7),
3. store-vs-recompute decisions for intermediates (the §5.3 ``z`` trick),
4. the per-phase HBM read/write/stream plan, honoring the *alignment
   constraint*: an input consumed by the SpMV (column/gather order) cannot
   be stream-shared with row-order consumers — the reason the paper reads
   ``p`` twice in phase 1.

Two policies:

* ``policy="paper"`` reproduces Callipepla exactly — ``z`` never stored,
  **M4→M5 re-executed in phase 3** (which also performs the store of
  ``r'``), giving the paper's §5.5 accounting: **14 accesses = 10 reads +
  4 writes** (19 = 14R + 5W naive).  On the FPGA this is forced by the
  decentralized FSM wiring: M5's phase-2 state has no memory-write port and
  adding one would add a 23rd FIFO to a routing-constrained design.
* ``policy="min_traffic"`` may store ``r'`` straight out of phase 2 —
  legal on TPU where a fused kernel has any number of outputs — dropping
  the M4 re-execution: **13 accesses = 9 reads + 4 writes**, strictly
  better than the paper.  First beyond-paper optimization (EXPERIMENTS.md).

The production solver (:mod:`repro.core.phases`) follows this schedule,
and the schedule→program compiler (:mod:`repro.core.compile`) lowers it
mechanically to a stream-ISA program for the batched VM
(:mod:`repro.core.vm`) — the compiler validates its emitted HBM traffic
against ``hbm_reads``/``hbm_writes`` phase by phase, so the 19 / 14 / 13
accounting asserted here is enforced at the instruction level too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

__all__ = ["Module", "JPCG_MODULES", "schedule", "access_counts", "VSRSchedule"]

#: loop-carried vectors: produced as v', consumed next iteration as v.
LOOP_CARRIED = {"r'": "r", "p'": "p", "x'": "x"}


@dataclasses.dataclass(frozen=True)
class Module:
    """One processing module (paper's M1–M8)."""

    name: str
    reads: Tuple[str, ...]            # vector inputs
    writes: Tuple[str, ...]           # vector outputs (() for dot modules)
    scalar_out: str | None = None     # scalar produced (dot modules)
    scalar_in: Tuple[str, ...] = ()   # scalars required
    heavy: bool = False               # streams the matrix operand (SpMV):
                                      # gather-ordered reads, not re-runnable


# Algorithm 1 loop body.  Unprimed names are previous-iteration values.
JPCG_MODULES: Tuple[Module, ...] = (
    Module("M1_spmv",    reads=("p",),        writes=("ap",), heavy=True),
    Module("M2_dot_pap", reads=("p", "ap"),   writes=(), scalar_out="alpha"),
    Module("M3_upd_x",   reads=("x", "p"),    writes=("x'",), scalar_in=("alpha",)),
    Module("M4_upd_r",   reads=("r", "ap"),   writes=("r'",), scalar_in=("alpha",)),
    Module("M5_div_z",   reads=("M", "r'"),   writes=("z",)),
    Module("M6_dot_rz",  reads=("r'", "z"),   writes=(), scalar_out="beta"),
    Module("M7_upd_p",   reads=("z", "p"),    writes=("p'",), scalar_in=("beta",)),
    Module("M8_dot_rr",  reads=("r'",),       writes=(), scalar_out="rr"),
)


@dataclasses.dataclass(frozen=True)
class VSRSchedule:
    policy: str
    phases: Tuple[Tuple[str, ...], ...]      # module names per phase (incl. re-runs)
    hbm_reads: Tuple[Tuple[str, ...], ...]   # vectors read from HBM per phase
    hbm_writes: Tuple[Tuple[str, ...], ...]  # vectors written to HBM per phase
    streamed: Tuple[Tuple[str, ...], ...]    # vectors handed off on-chip per phase
    recomputed: Tuple[str, ...]              # modules re-executed in a later phase
    never_stored: Tuple[str, ...]            # vectors that never touch HBM

    @property
    def n_reads(self) -> int:
        return sum(len(r) for r in self.hbm_reads)

    @property
    def n_writes(self) -> int:
        return sum(len(w) for w in self.hbm_writes)

    @property
    def n_accesses(self) -> int:
        return self.n_reads + self.n_writes


def _earliest_levels(modules: Sequence[Module]) -> Dict[str, int]:
    """Earliest phase per module: scalar deps are barriers (+1), vector deps
    keep producers no later than consumers (same phase allowed: streaming)."""
    scalar_prod = {m.scalar_out: m.name for m in modules if m.scalar_out}
    vec_prod = {v: m.name for m in modules for v in m.writes}
    by_name = {m.name: m for m in modules}
    level: Dict[str, int] = {}

    def lvl(name: str) -> int:
        if name in level:
            return level[name]
        m = by_name[name]
        dep = 0
        for s in m.scalar_in:
            dep = max(dep, lvl(scalar_prod[s]) + 1)
        for v in m.reads:
            if v in vec_prod:
                dep = max(dep, lvl(vec_prod[v]))
        level[name] = dep
        return dep

    for m in modules:
        lvl(m.name)
    return level


def _topo_order(names: List[str], by_name: Dict[str, Module]) -> List[str]:
    """Order modules within a phase so producers precede consumers."""
    produced = {v: n for n in names for v in by_name[n].writes}
    out: List[str] = []
    visiting: set = set()

    def visit(n: str):
        if n in out or n in visiting:
            return
        visiting.add(n)
        for v in by_name[n].reads:
            if v in produced and produced[v] != n:
                visit(produced[v])
        visiting.discard(n)
        out.append(n)

    for n in names:
        visit(n)
    return out


def schedule(modules: Sequence[Module] = JPCG_MODULES,
             policy: str = "paper") -> VSRSchedule:
    """Compute the VSR schedule under ``policy`` ("paper" | "min_traffic")."""
    if policy not in ("paper", "min_traffic"):
        raise ValueError(f"unknown policy {policy!r}")
    by_name = {m.name: m for m in modules}
    vec_prod = {v: m.name for m in modules for v in m.writes}
    level = _earliest_levels(modules)
    n_phases = max(level.values()) + 1

    # --- sink pass: a module that writes only loop-carried vectors (no
    # intra-iteration consumer, no scalar output) may run in any phase >=
    # its earliest; run it in the last phase, where stream-sharing
    # opportunities are maximal (reproduces the paper's M3 -> phase 3).
    # Dot modules are never sunk: their scalars gate later phases, and the
    # paper deliberately hoists M8 (rr) early for on-the-fly termination.
    placement = dict(level)
    for m in modules:
        if not m.writes or m.scalar_out is not None:
            continue
        consumers = [level[o.name] for o in modules
                     for v in m.writes if v in o.reads]
        latest = min(consumers) if consumers else n_phases - 1
        if latest > placement[m.name]:
            placement[m.name] = latest

    base_phases: List[List[str]] = [
        [m.name for m in modules if placement[m.name] == p] for p in range(n_phases)]

    consumed_in: Dict[str, List[int]] = {}
    for m in modules:
        for v in m.reads:
            consumed_in.setdefault(v, []).append(placement[m.name])

    # --- store vs recompute ------------------------------------------------
    # Intermediates (not loop-carried) consumed in a later phase: recompute
    # if the producer chain is light (no SpMV), else store.
    stored_at: Dict[str, int] = {}          # vector -> phase of its HBM write
    never_stored: List[str] = []
    rerun_into: Dict[int, List[str]] = {}   # phase -> re-executed module chain

    def light_chain(name: str, target_phase: int) -> List[str] | None:
        """Modules to re-run in target_phase, reading only HBM-stored vectors."""
        m = by_name[name]
        if m.heavy:
            return None
        chain: List[str] = []
        for v in m.reads:
            if v in vec_prod:
                producer = vec_prod[v]
                if v in stored_at and stored_at[v] < target_phase:
                    continue                  # already in HBM by then
                sub = light_chain(producer, target_phase)
                if sub is None:
                    return None
                chain.extend(sub)
        chain.append(name)
        return list(dict.fromkeys(chain))

    # Loop-carried vectors must reach HBM.  Under the paper policy r' may
    # only be written by M5's phase-3 pass-through (FSM port constraint).
    for v in LOOP_CARRIED:
        p = placement[vec_prod[v]]
        if policy == "paper" and v == "r'":
            stored_at[v] = n_phases - 1
        else:
            stored_at[v] = p

    for v, prod in vec_prod.items():
        p = placement[prod]
        later = sorted({q for q in consumed_in.get(v, []) if q > p})
        if v in LOOP_CARRIED:
            continue
        if not later:
            if any(q == p for q in consumed_in.get(v, [])) and len(
                    consumed_in.get(v, [])) >= 0:
                pass
            continue
        chain = light_chain(prod, later[0])
        if chain is not None:
            never_stored.append(v)
            for q in later:
                ch = light_chain(prod, q) or []
                rerun_into.setdefault(q, []).extend(ch)
        else:
            stored_at[v] = p   # e.g. ap: SpMV output, must be stored

    # Under the paper policy the phase-3 rerun of M4 regenerates r' and is
    # the store of record for it; record that rerun explicitly.
    if policy == "paper":
        rp = stored_at["r'"]
        if vec_prod["r'"] not in rerun_into.get(rp, []) and placement[
                vec_prod["r'"]] != rp:
            chain = ["M4_upd_r"] if "M4_upd_r" in by_name else []
            rerun_into.setdefault(rp, [])
            # r' producer must come before its consumers in that phase
            rerun_into[rp] = chain + rerun_into[rp]

    recomputed = sorted({n for ch in rerun_into.values() for n in ch})

    # --- per-phase HBM plan --------------------------------------------------
    phases, hbm_reads, hbm_writes, streamed = [], [], [], []
    for p in range(n_phases):
        active = _topo_order(
            list(dict.fromkeys(base_phases[p] + rerun_into.get(p, []))), by_name)
        reads: List[str] = []
        writes: List[str] = []
        streams: List[str] = []
        produced_here: set = set()
        # alignment constraint: gather-order reads (heavy modules) can't share
        shareable_reads: set = set()
        for name in active:
            m = by_name[name]
            for v in m.reads:
                if v in produced_here:
                    if v not in streams:
                        streams.append(v)        # on-chip producer hand-off
                elif v in shareable_reads:
                    streams.append(v)            # second consumer, one read
                else:
                    reads.append(v)
                    if not m.heavy:
                        shareable_reads.add(v)
            produced_here.update(m.writes)
        for name in active:
            for v in by_name[name].writes:
                if v in never_stored:
                    continue
                if stored_at.get(v) == p and v not in writes:
                    writes.append(v)
        phases.append(tuple(active))
        # NOTE: reads may legitimately repeat (phase 1 reads `p` twice: the
        # SpMV's gather-ordered pass cannot be shared with M2's row-ordered
        # pass) — duplicates are distinct HBM accesses and must be counted.
        hbm_reads.append(tuple(reads))
        hbm_writes.append(tuple(dict.fromkeys(writes)))
        streamed.append(tuple(dict.fromkeys(streams)))

    return VSRSchedule(policy=policy, phases=tuple(phases),
                       hbm_reads=tuple(hbm_reads), hbm_writes=tuple(hbm_writes),
                       streamed=tuple(streamed), recomputed=tuple(recomputed),
                       never_stored=tuple(dict.fromkeys(never_stored)))


def access_counts(modules: Sequence[Module] = JPCG_MODULES) -> Dict[str, Dict[str, int]]:
    """Paper §5.5 accounting: naive 19 (14R+5W), paper-VSR 14 (10R+4W),
    and our min-traffic schedule 13 (9R+4W)."""
    naive_reads = sum(len(m.reads) for m in modules)
    naive_writes = sum(len(m.writes) for m in modules)
    out = {"naive": {"reads": naive_reads, "writes": naive_writes,
                     "total": naive_reads + naive_writes}}
    for pol in ("paper", "min_traffic"):
        s = schedule(modules, policy=pol)
        out[pol] = {"reads": s.n_reads, "writes": s.n_writes,
                    "total": s.n_accesses}
    return out
