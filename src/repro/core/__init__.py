"""Callipepla core: stream-centric mixed-precision JPCG for JAX/TPU.

The paper's three contributions, as composable pieces:

* :mod:`repro.core.precision` — Mix-V1/V2/V3 + TPU-tier schemes (§6);
* :mod:`repro.core.vsr` — vector-streaming-reuse scheduling (§5);
* :mod:`repro.core.phases` / :mod:`repro.core.cg` — the production solver;
* :mod:`repro.core.batch` — batched multi-system JPCG (one compiled loop,
  per-problem on-the-fly termination);
* :mod:`repro.core.isa` / :mod:`repro.core.compile` / :mod:`repro.core.vm`
  — the stream-centric instruction set (§4), the schedule→program
  compiler, and the batched stream VM (§3–4) that is the default solver
  backend (see ARCHITECTURE.md for the pipeline);
* :mod:`repro.core.pipelined` — beyond-paper single-reduction CG;
* :mod:`repro.core.gn` — matrix-free Gauss–Newton operators (CGGN bridge).
"""
from repro.core.cg import CGResult, jpcg_solve
from repro.core.batch import jpcg_solve_batched
from repro.core.compile import compile_policy, compile_schedule
from repro.core.precision import SCHEMES, PrecisionScheme, get_scheme
from repro.core.vsr import access_counts, schedule

__all__ = ["CGResult", "jpcg_solve", "jpcg_solve_batched", "SCHEMES", "PrecisionScheme",
           "get_scheme", "access_counts", "schedule", "compile_policy",
           "compile_schedule"]
