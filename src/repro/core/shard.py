"""Lane-axis sharding — G independent solves data-parallel over a mesh.

The batched solver and the serving engine widen every per-system array
by a leading (or second) lane axis ``G``.  Lanes are *independent* by
construction — every VM op is lane-elementwise, the only cross-lane
value in the whole loop is the ``jnp.any(active)`` termination
predicate — so the serving-scale layout is the same one a batched
inference engine uses for its batch axis: shard the lane axis over a
1-D device mesh with :class:`jax.sharding.NamedSharding` and let SPMD
partitioning run ``G/D`` lanes per device with zero per-iteration
collectives (the ``any`` reduce happens once per sync chunk, and
admit/harvest cross the host boundary exactly as they do on one
device).

Because each device's local block is just a smaller lane bucket — and
lane-count invariance is already a locked invariant of the solver
(pool compaction repacks lanes bitwise-neutrally) — a sharded solve is
**bit-identical** to the single-device one, which ``tests/test_shard.py``
asserts for every scheme × layout × engine.

This module holds the small amount of shared plumbing:

* :func:`lane_mesh` — build the 1-D ``("lanes",)`` mesh;
* :func:`mesh_shards` / :func:`mesh_signature` — fold a mesh to its
  shard count / to the hashable token that joins
  :func:`repro.core.compile.executable_key` (single-device and sharded
  executables must never collide in the cache);
* :func:`pad_lanes` — round a lane count up to a shard-divisible size
  (``NamedSharding`` needs the lane axis evenly divisible);
* :func:`place_lanes` / :func:`place_replicated` /
  :func:`place_vm_state` — ``device_put`` operands and VM state with
  the lane axis sharded and everything else replicated.

On CPU the mesh comes from
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI lane
sets 8); a 1-device mesh is valid everywhere and exercises the same
code path, which is how the sharding tests stay green on a bare image.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["LANE_AXIS", "lane_mesh", "mesh_shards", "mesh_signature",
           "pad_lanes", "lane_sharding", "place_lanes",
           "place_replicated", "place_vm_state"]

#: Canonical mesh axis name for the lane (batch-of-systems) dimension.
LANE_AXIS = "lanes"


def lane_mesh(devices: Optional[Sequence] = None,
              axis_name: str = LANE_AXIS) -> Mesh:
    """1-D lane mesh over ``devices`` (default: every visible device)."""
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.array(devs), (axis_name,))


def mesh_shards(mesh: Optional[Mesh]) -> int:
    """Number of lane shards D (1 for ``mesh=None`` — the unsharded path)."""
    if mesh is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names], dtype=np.int64))


def mesh_signature(mesh) -> Optional[Tuple[Tuple[str, int], ...]]:
    """Hashable cache-key token of a mesh: ``((axis, size), ...)``.

    ``None`` stays ``None`` (the unsharded key), so a 1-device mesh is
    deliberately *distinct* from no mesh at all — the executables differ
    (sharded operand layouts are baked in at trace time) and must not
    collide.  Accepts an already-folded signature unchanged, so callers
    can pass either form down to :func:`repro.core.compile.executable_key`.
    """
    if mesh is None:
        return None
    if isinstance(mesh, tuple):
        return mesh
    return tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names)


def pad_lanes(g: int, mesh: Optional[Mesh]) -> int:
    """Smallest lane count ≥ ``g`` that the mesh divides evenly.

    ``NamedSharding`` requires the sharded axis to divide by the shard
    count; the batched front door pads the problem list up to this with
    inert identity lanes (converged at admission, dropped from results).
    """
    d = mesh_shards(mesh)
    return int(-(-max(int(g), 1) // d) * d)


def lane_sharding(mesh: Mesh, ndim: int, lane_axis: int = 0) -> NamedSharding:
    """NamedSharding partitioning ``lane_axis`` over the mesh, rest
    replicated."""
    spec = [None] * ndim
    spec[lane_axis] = mesh.axis_names if len(mesh.axis_names) > 1 \
        else mesh.axis_names[0]
    return NamedSharding(mesh, PartitionSpec(*spec))


def place_lanes(mesh: Optional[Mesh], arrays, lane_axis: int = 0):
    """``device_put`` array(s) with the lane axis sharded over the mesh.

    Accepts one array or a tuple/list of arrays that all carry their
    lane axis at the same position.  No-op for ``mesh=None``, and cheap
    when an array already has the target sharding (``device_put``
    short-circuits).
    """
    if mesh is None:
        return arrays
    def put(a):
        return jax.device_put(a, lane_sharding(mesh, np.ndim(a), lane_axis))
    if isinstance(arrays, (tuple, list)):
        return type(arrays)(put(a) for a in arrays)
    return put(arrays)


def place_replicated(mesh: Optional[Mesh], x):
    """``device_put`` a value fully replicated over the mesh."""
    if mesh is None:
        return x
    return jax.device_put(x, NamedSharding(mesh, PartitionSpec()))


def place_vm_state(mesh: Optional[Mesh], state):
    """Lay a :class:`repro.core.vm.BatchedVMState` out over the mesh.

    ``mem``/``queues``/``sregs`` carry the lane axis at position 1
    (buffer/queue/register id leads), everything else at position 0;
    the global tick ``k`` is replicated.
    """
    if mesh is None:
        return state
    return state._replace(
        k=place_replicated(mesh, state.k),
        it=place_lanes(mesh, state.it),
        status=place_lanes(mesh, state.status),
        mem=place_lanes(mesh, state.mem, lane_axis=1),
        queues=place_lanes(mesh, state.queues, lane_axis=1),
        sregs=place_lanes(mesh, state.sregs, lane_axis=1),
        active=place_lanes(mesh, state.active),
        trace=place_lanes(mesh, state.trace))
