"""Stream-centric instruction set (paper §4) — encodings and assembler.

Three instruction types (paper Fig. 2), encoded as int32 words so a whole
*program* is a single ``int32[P, 8]`` array — a traced operand of the VM,
not a Python structure.  Changing the program therefore does **not**
retrace/recompile the executor: the XLA-compiled VM binary plays the role
of the FPGA bitstream, and programs play the role of the instruction
streams the global controller issues.  This is the paper's Challenge-1
goal ("support an arbitrary problem once deployed") transplanted to JAX.

Word layout (int32[8]):

  =====  =============================================================
  field  meaning
  =====  =============================================================
  0      itype: 0=VCTRL (Type-I), 1=COMP (Type-II), 2=CTRL (scalar op),
         3=NOP
  1      VCTRL: memory buffer id · COMP: module id (0..7 = M1..M8) ·
         CTRL: 0 -> α = rz/pap, 1 -> β = rz_new/rz ; rz ← rz_new
  2      VCTRL: rd flag · COMP: sign flag for the axpy scalar (0:+, 1:−)
  3      VCTRL: wr flag
  4      src queue a
  5      src queue b
  6      dst queue (VCTRL rd / COMP vector output)
  7      scalar register index (COMP: axpy reads it, dots write it)
  =====  =============================================================

Type-III memory instructions are *derived*: a VCTRL instruction with
rd/wr set makes its vector-control module issue the corresponding
InstRdWr to the memory engine (paper §4.2: "VecCtrl-1 will issue a memory
instruction InstRdWr{...} to the memory module").
:func:`derived_mem_instructions` returns them, and the tests assert their
count equals the §5.5 accounting (10 reads + 4 writes for the paper
schedule).

Memory buffers: 0=x, 1=r, 2=p, 3=ap, 4=M (diagonal), 5=b.
Scalar registers: 0=α, 1=β, 2=rz, 3=rr, 4=pap, 5=rz_new.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = [
    "ITYPE_VCTRL", "ITYPE_COMP", "ITYPE_CTRL", "ITYPE_NOP",
    "MOD", "BUF", "SREG", "Instr", "assemble_jpcg", "derived_mem_instructions",
    "decode_program", "program_text", "pad_program", "program_token",
]

ITYPE_VCTRL, ITYPE_COMP, ITYPE_CTRL, ITYPE_NOP = 0, 1, 2, 3

#: computation modules, paper Fig. 1 (index = module id)
MOD = {"M1_spmv": 0, "M2_dot_pap": 1, "M3_upd_x": 2, "M4_upd_r": 3,
       "M5_div_z": 4, "M6_dot_rz": 5, "M7_upd_p": 6, "M8_dot_rr": 7}

BUF = {"x": 0, "r": 1, "p": 2, "ap": 3, "M": 4, "b": 5}
SREG = {"alpha": 0, "beta": 1, "rz": 2, "rr": 3, "pap": 4, "rz_new": 5}

CTRL_ALPHA, CTRL_BETA = 0, 1


@dataclasses.dataclass(frozen=True)
class Instr:
    itype: int
    f1: int = 0
    rd: int = 0
    wr: int = 0
    qa: int = 0
    qb: int = 0
    qd: int = 0
    sreg: int = 0

    def encode(self) -> List[int]:
        return [self.itype, self.f1, self.rd, self.wr,
                self.qa, self.qb, self.qd, self.sreg]


def _rd(buf: str, qd: int) -> Instr:
    return Instr(ITYPE_VCTRL, BUF[buf], rd=1, qd=qd)


def _wr(buf: str, qs: int) -> Instr:
    return Instr(ITYPE_VCTRL, BUF[buf], wr=1, qa=qs)


def _comp(mod: str, qa: int, qb: int = 0, qd: int = 0, sreg: str = "alpha",
          neg: bool = False) -> Instr:
    return Instr(ITYPE_COMP, MOD[mod], rd=int(neg), qa=qa, qb=qb, qd=qd,
                 sreg=SREG[sreg])


def _ctrl(which: int) -> Instr:
    return Instr(ITYPE_CTRL, which)


def assemble_jpcg(policy: str = "paper") -> Tuple[np.ndarray, List[Instr]]:
    """Emit one JPCG iteration under the VSR schedule — *golden reference*.

    Returns (encoded int32[P, 8] program, decoded instruction list).
    The two policies differ exactly as :mod:`repro.core.vsr` computes:
    ``paper`` re-runs M4+M5 in phase 3 (r' stored by the re-run pass-
    through), ``min_traffic`` stores r' straight out of phase 2.

    Production programs come from the schedule→program compiler
    (:func:`repro.core.compile.compile_policy`), which must reproduce this
    hand assembly word for word for the paper policy — the lock lives in
    ``tests/test_compile.py``.  This function stays as the human-audited
    transcription of the paper's Fig. 2 / §5.5 controller sequence.
    """
    P: List[Instr] = []
    # ------- Phase 1: M1 (SpMV), M2 (dot) --------------------------------
    P += [_rd("p", qd=0),                                   # p -> M1
          _comp("M1_spmv", qa=0, qd=1),                     # ap stream
          _rd("p", qd=2),                                   # p -> M2 (2nd read:
          _comp("M2_dot_pap", qa=2, qb=1, sreg="pap"),      #  gather-order mismatch)
          _wr("ap", qs=1),                                  # ap store
          _ctrl(CTRL_ALPHA)]                                # α = rz/pap
    # ------- Phase 2: M4, M8, M5, M6 --------------------------------------
    P += [_rd("r", qd=0),
          _rd("ap", qd=1),
          _comp("M4_upd_r", qa=0, qb=1, qd=2, sreg="alpha", neg=True),  # r'
          _comp("M8_dot_rr", qa=2, qb=2, sreg="rr")]        # hoisted: early exit
    if policy == "min_traffic":
        P += [_wr("r", qs=2)]                               # store r' now (13-access)
    P += [_rd("M", qd=3),
          _comp("M5_div_z", qa=2, qb=3, qd=4),              # z (never stored)
          _comp("M6_dot_rz", qa=2, qb=4, sreg="rz_new"),
          _ctrl(CTRL_BETA)]                                 # β = rz'/rz ; rz ← rz'
    # ------- Phase 3: (recompute M4, M5), M7, M3 ---------------------------
    if policy == "paper":
        P += [_rd("r", qd=0),
              _rd("ap", qd=1),
              _comp("M4_upd_r", qa=0, qb=1, qd=2, sreg="alpha", neg=True),
              _wr("r", qs=2),                               # r' store of record
              _rd("M", qd=3),
              _comp("M5_div_z", qa=2, qb=3, qd=4)]          # z recomputed
    else:
        P += [_rd("r", qd=2),                               # r' from HBM
              _rd("M", qd=3),
              _comp("M5_div_z", qa=2, qb=3, qd=4)]          # z recomputed (light)
    P += [_rd("p", qd=5),
          _comp("M7_upd_p", qa=4, qb=5, qd=6, sreg="beta"),  # p' = z + β·p
          _wr("p", qs=6),
          _rd("x", qd=7),
          _comp("M3_upd_x", qa=7, qb=5, qd=6, sreg="alpha"),  # x' = x + α·p
          _wr("x", qs=6)]                                   # (p stream reused ✓)
    enc = np.asarray([i.encode() for i in P], dtype=np.int32)
    return enc, P


def derived_mem_instructions(program: np.ndarray) -> dict:
    """Type-III InstRdWr stream a program's VCTRL instructions generate."""
    vctrl = program[program[:, 0] == ITYPE_VCTRL]
    reads = int(vctrl[:, 2].sum())
    writes = int(vctrl[:, 3].sum())
    return {"reads": reads, "writes": writes, "total": reads + writes}


def decode_program(program: np.ndarray) -> List[Instr]:
    """Decode an int32[P, 8] word array back to :class:`Instr` records."""
    return [Instr(*(int(v) for v in w)) for w in np.asarray(program)]


def program_text(program: np.ndarray) -> str:
    """Human-readable disassembly (one line per word) — for test diffs
    and ARCHITECTURE.md walkthroughs, not for execution."""
    buf_of = {v: k for k, v in BUF.items()}
    mod_of = {v: k for k, v in MOD.items()}
    sreg_of = {v: k for k, v in SREG.items()}
    lines = []
    for pc, i in enumerate(decode_program(program)):
        if i.itype == ITYPE_VCTRL:
            op = (f"rd   {buf_of[i.f1]:2s} -> q{i.qd}" if i.rd
                  else f"wr   {buf_of[i.f1]:2s} <- q{i.qa}")
        elif i.itype == ITYPE_COMP:
            mod = mod_of[i.f1]
            if mod in ("M2_dot_pap", "M6_dot_rz", "M8_dot_rr"):
                op = f"{mod}: s[{sreg_of[i.sreg]}] = q{i.qa}.q{i.qb}"
            elif mod == "M1_spmv":
                op = f"{mod}: q{i.qd} = A @ q{i.qa}"
            elif mod == "M5_div_z":
                op = f"{mod}: q{i.qd} = q{i.qa} / q{i.qb}"
            else:
                sign = "-" if i.rd else "+"
                op = (f"{mod}: q{i.qd} = q{i.qa} {sign} "
                      f"s[{sreg_of[i.sreg]}]*q{i.qb}")
        elif i.itype == ITYPE_CTRL:
            op = ("ctrl alpha = rz/pap" if i.f1 == CTRL_ALPHA
                  else "ctrl beta = rz'/rz ; rz <- rz'")
        else:
            op = "nop"
        lines.append(f"{pc:3d}  {op}")
    return "\n".join(lines)


def program_token(program: np.ndarray) -> str:
    """Stable content hash of an ``int32[P, 8]`` program word array.

    Two programs share a token iff they are word-identical (NOP padding
    included — the padded words are the bytes that run).  This is the
    cache-key component of the *specialized* VM path
    (:func:`repro.core.vm.make_vm_runner` with ``program=``): program
    bytes participate in executable identity only there, never on the
    generic traced-operand path.
    """
    import hashlib
    words = np.ascontiguousarray(np.asarray(program, dtype=np.int32))
    return hashlib.sha1(words.tobytes()).hexdigest()[:16]


def pad_program(program: np.ndarray, length: int) -> np.ndarray:
    """NOP-pad so differently-scheduled programs share one compiled VM."""
    if program.shape[0] > length:
        raise ValueError(f"program length {program.shape[0]} > pad {length}")
    pad = np.zeros((length - program.shape[0], 8), dtype=np.int32)
    pad[:, 0] = ITYPE_NOP
    return np.concatenate([program, pad], axis=0)
