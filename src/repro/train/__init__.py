"""Training substrate: optimizers, CGGN, loop, data, checkpoint, fault."""
from repro.train.cggn import CGGNConfig, CGGNState, cggn_init, cggn_update
from repro.train.data import DataConfig, SyntheticLM
from repro.train.loop import Trainer, TrainerConfig, make_train_step
from repro.train.optim import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "CGGNConfig", "CGGNState", "cggn_init", "cggn_update",
           "DataConfig", "SyntheticLM", "Trainer", "TrainerConfig",
           "make_train_step"]
