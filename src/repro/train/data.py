"""Deterministic synthetic LM data pipeline with an explicit cursor.

Production data loaders are stateful; fault tolerance demands the state be
*checkpointable and exact*.  Here the pipeline is a pure function of
``(seed, step)`` — ``batch_at(step)`` — so the "cursor" in a checkpoint is
just the step integer, restarts are bitwise reproducible, and elastic
re-meshes need no loader coordination (DESIGN.md §5).

Two sources:
* ``markov``  — an order-1 Markov chain over the vocab with a banded
  transition kernel: enough structure that a ~100M model visibly learns
  (examples/train driver), zero I/O.
* ``uniform`` — i.i.d. tokens (pure-throughput benchmarking).

Labels are next-token shifted; the final position predicts token 0 (BOS).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "markov"        # "markov" | "uniform"
    band: int = 16                # markov: next token within +-band of prev


class SyntheticLM:
    """Stateless-per-step synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._root = jax.random.PRNGKey(cfg.seed)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(self._root, step)
        if cfg.source == "uniform":
            toks = jax.random.randint(
                key, (cfg.global_batch, cfg.seq_len), 0, cfg.vocab, jnp.int32)
        elif cfg.source == "markov":
            k0, kw = jax.random.split(key)
            start = jax.random.randint(k0, (cfg.global_batch,), 0, cfg.vocab)
            steps = jax.random.randint(
                kw, (cfg.global_batch, cfg.seq_len - 1), -cfg.band,
                cfg.band + 1)

            def walk(tok, d):
                nxt = (tok + d) % cfg.vocab
                return nxt, nxt

            _, rest = jax.lax.scan(walk, start, steps.T)
            toks = jnp.concatenate([start[:, None], rest.T],
                                   axis=1).astype(jnp.int32)
        else:
            raise ValueError(f"unknown source {cfg.source!r}")
        labels = jnp.concatenate(
            [toks[:, 1:], jnp.zeros((cfg.global_batch, 1), jnp.int32)],
            axis=1)
        return {"tokens": toks, "labels": labels}

    def cursor(self, step: int) -> Dict[str, int]:
        """Checkpointable loader state — the step is the whole cursor."""
        return {"seed": self.cfg.seed, "step": step,
                "source": self.cfg.source}
