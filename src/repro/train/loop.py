"""Train-step builder + host training loop (checkpoint / fault hooks).

``make_train_step`` returns one jitted function

    train_step(params, opt_state, batch, step) -> (params', opt_state',
                                                   metrics)

with: microbatch gradient accumulation (a ``lax.scan`` over the leading
batch split — the global_batch=256 shapes run as k microbatches), fp32
loss/grad math over bf16 compute, AdamW with bf16 moment storage, explicit
in/out shardings from :mod:`repro.distributed.sharding`, and donated
params/opt-state (the framework-level double-channel ping-pong: XLA
aliases the update in place, DESIGN.md §2).

``Trainer`` is the host loop: deterministic data cursor, periodic atomic
checkpoints, straggler deadline via :mod:`repro.train.fault`, resume.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (activation_spec, batch_specs,
                                        named_shardings, param_specs)
from repro.models.api import loss_fn
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt
from repro.train.fault import StepWatchdog
from repro.train.optim import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule)

__all__ = ["make_train_step", "Trainer", "TrainerConfig"]


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh] = None, *,
                    opt: AdamWConfig = AdamWConfig(),
                    schedule: Optional[Callable] = None,
                    microbatches: int = 1,
                    params_shape: Any = None,
                    donate: bool = True):
    """Build the jitted train step (optionally sharded over ``mesh``).

    ``params_shape`` (ShapeDtypeStruct tree) is needed only when ``mesh``
    is given, to derive in/out shardings without materializing params.
    """
    schedule = schedule or cosine_schedule(opt.lr, 100, 10_000)

    def _loss_micro(params, micro):
        return loss_fn(params, cfg, micro)

    def step_fn(params, opt_state, batch, step):
        if microbatches > 1:
            def split(x):
                # strided split keeps every microbatch spanning all data
                # shards (see launch/dryrun.py)
                return x.reshape(x.shape[0] // microbatches, microbatches,
                                 *x.shape[1:]).swapaxes(0, 1)
            micros = jax.tree_util.tree_map(split, batch)

            def accum(carry, micro):
                l, g = jax.value_and_grad(_loss_micro)(params, micro)
                carry = (carry[0] + l,
                         jax.tree_util.tree_map(jnp.add, carry[1], g))
                return carry, None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (tot_l, tot_g), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero_g), micros)
            loss = tot_l / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, tot_g)
        else:
            loss, grads = jax.value_and_grad(_loss_micro)(params, batch)

        lr = schedule(step)
        new_params, new_opt = adamw_update(grads, opt_state, params, opt, lr)
        metrics = {"loss": loss, "lr": lr}
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())

    assert params_shape is not None, "mesh mode needs params_shape"
    pspecs = param_specs(params_shape, mesh)
    p_shard = named_shardings(pspecs, mesh)
    # moments mirror the param specs; step scalar replicated
    opt_shape = jax.eval_shape(partial(adamw_init, cfg=opt), params_shape)
    o_shard = type(opt_shape)(
        step=NamedSharding(mesh, P()),
        m=named_shardings(pspecs, mesh),
        v=named_shardings(pspecs, mesh))

    def in_batch_shardings(batch_shape):
        return named_shardings(batch_specs(batch_shape, mesh), mesh)

    def jit_for(batch_shape):
        return jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, in_batch_shardings(batch_shape),
                          NamedSharding(mesh, P())),
            out_shardings=(p_shard, o_shard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1) if donate else ())

    return jit_for


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    step_deadline_s: Optional[float] = None     # straggler budget


class Trainer:
    """Host loop: data cursor + checkpoints + watchdog + resume."""

    def __init__(self, cfg: ModelConfig, data, train_step, params,
                 opt_state, tcfg: TrainerConfig,
                 key: Optional[jax.Array] = None):
        self.cfg = cfg
        self.data = data
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.tcfg = tcfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.step = 0
        self.metrics_log = []
        self.watchdog = StepWatchdog(tcfg.step_deadline_s)

    # ---- fault tolerance ------------------------------------------------
    def save(self):
        tree = {"params": self.params, "opt": self.opt_state,
                "key": self.key}
        meta = {"cursor": self.data.cursor(self.step),
                "arch": self.cfg.name}
        ckpt.save(self.tcfg.ckpt_dir, self.step, tree, meta)

    def try_resume(self) -> bool:
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        template = {"params": self.params, "opt": self.opt_state,
                    "key": self.key}
        tree, meta = ckpt.restore(self.tcfg.ckpt_dir, template, step=last)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.key = tree["key"]
        self.step = meta["cursor"]["step"]
        return True

    # ---- the loop ---------------------------------------------------------
    def run(self, steps: Optional[int] = None):
        end = self.step + (steps if steps is not None
                           else self.tcfg.total_steps)
        while self.step < end:
            batch = self.data.batch_at(self.step)
            with self.watchdog.guard(self.step):
                t0 = time.monotonic()
                self.params, self.opt_state, m = self.train_step(
                    self.params, self.opt_state, batch,
                    jnp.asarray(self.step, jnp.int32))
                m = jax.tree_util.tree_map(float, m)
                m["step_time_s"] = time.monotonic() - t0
            self.metrics_log.append({"step": self.step, **m})
            if self.tcfg.log_every and self.step % self.tcfg.log_every == 0:
                print(f"step {self.step:5d}  loss {m['loss']:.4f}  "
                      f"({m['step_time_s']*1e3:.0f} ms)")
            self.step += 1
            if self.tcfg.ckpt_every and self.step % self.tcfg.ckpt_every == 0:
                self.save()
        return self.metrics_log
