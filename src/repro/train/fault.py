"""Fault tolerance — straggler watchdog, retries, elastic re-mesh.

At 1000+ nodes the failure model is: (a) slow steps (stragglers —
network/preemption), (b) lost workers (restart from checkpoint), and
(c) changed topology on restart (elastic re-mesh).  The pieces here:

* :class:`StepWatchdog` — per-step wall-clock deadline.  A breach is
  recorded and (policy) either logged-and-continued or escalated after
  ``max_breaches`` consecutive slow steps (on a real cluster: trigger
  re-dispatch; here: raise ``StragglerError`` so the driver can restart
  from the last checkpoint — exercised in tests).
* :func:`with_retries` — wraps a step with bounded retries for transient
  faults (the injected-fault tests use this path).
* :func:`elastic_restore` — restore a checkpoint onto a DIFFERENT mesh:
  checkpoints are mesh-independent (full logical arrays + named-axis
  specs), so only the re-sharding changes.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Optional

import jax

from repro.distributed.sharding import named_shardings, param_specs
from repro.train import checkpoint as ckpt

__all__ = ["StragglerError", "StepWatchdog", "with_retries",
           "elastic_restore"]


class StragglerError(RuntimeError):
    """Raised after too many consecutive deadline breaches."""


class StepWatchdog:
    def __init__(self, deadline_s: Optional[float],
                 max_breaches: int = 3):
        self.deadline_s = deadline_s
        self.max_breaches = max_breaches
        self.breaches = 0
        self.consecutive = 0
        self.slow_steps = []

    @contextlib.contextmanager
    def guard(self, step: int):
        t0 = time.monotonic()
        yield
        dt = time.monotonic() - t0
        if self.deadline_s is not None and dt > self.deadline_s:
            self.breaches += 1
            self.consecutive += 1
            self.slow_steps.append((step, dt))
            if self.consecutive >= self.max_breaches:
                raise StragglerError(
                    f"{self.consecutive} consecutive steps over the "
                    f"{self.deadline_s}s deadline (last: {dt:.2f}s at "
                    f"step {step})")
        else:
            self.consecutive = 0


def with_retries(fn: Callable, *args, retries: int = 2,
                 retry_on=(RuntimeError,), on_retry: Callable = None,
                 **kwargs):
    """Run ``fn`` with bounded retries on transient faults."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn(*args, **kwargs)
        except retry_on as e:          # noqa: PERF203
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
    raise last


def elastic_restore(root: str, template: Any, new_mesh, *,
                    step: Optional[int] = None):
    """Restore params (and anything mirroring their structure) onto
    ``new_mesh`` — the saved mesh's factorization is irrelevant because
    leaves are stored unsharded (checkpoint.py)."""
    specs = param_specs(template, new_mesh)
    shardings = named_shardings(specs, new_mesh)
    return ckpt.restore(root, template, step=step, shardings=shardings)
