"""Atomic, versioned, mesh-independent checkpoints.

Layout (one directory per step)::

    <root>/step_000123.tmp/      # staged write
        arrays.npz               # every leaf, host numpy, full (unsharded)
        manifest.json            # treedef, shapes/dtypes, sha256, metadata
    <root>/step_000123/          # atomic os.replace on success

Guarantees:
* **atomic** — a crash mid-write leaves only ``*.tmp``; ``latest_step``
  ignores them, ``restore`` never sees a torn checkpoint;
* **verified** — the manifest stores a sha256 over the array payload;
  mismatch raises instead of resuming silently corrupt state;
* **mesh-independent** — leaves are saved *unsharded* with their logical
  shapes, so a restart may use a different (data, model) factorization or
  device count: ``restore(..., shardings=...)`` re-shards on load (elastic
  re-mesh, tested save(mesh A) → restore(mesh B));
* **complete** — params, optimizer state, data cursor, and RNG key all
  live in one tree: resume is bitwise deterministic on CPU.

(At real pod scale the npz payload would be a tensorstore/OCDBT spec per
shard; the atomicity/versioning/manifest logic here is the part that
carries over unchanged.)
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "list_steps"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): leaf for path, leaf in flat}


def save(root: str, step: int, tree: Any,
         metadata: Optional[Dict] = None) -> str:
    """Stage + atomically publish one checkpoint.  Returns final path."""
    os.makedirs(root, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(root, name + ".tmp")
    final = os.path.join(root, name)
    os.makedirs(tmp, exist_ok=True)

    named = _flatten_with_names(tree)
    arrays = {}
    dtypes = {}
    for k, v in named.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype == jnp.bfloat16:          # numpy can't serialize bf16
            a = a.view(np.uint16)
        arrays[k] = a
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    digest = hashlib.sha256(payload).hexdigest()
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(payload)

    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "sha256": digest,
        "treedef": str(treedef),
        "leaves": {k: {"shape": list(a.shape), "dtype": dtypes[k]}
                   for k, a in arrays.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(final):                    # idempotent re-save
        import shutil
        shutil.rmtree(final)
    os.replace(tmp, final)                       # the atomic publish
    return final


def list_steps(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        m = _STEP_RE.match(d)
        if m and os.path.isfile(os.path.join(root, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore(root: str, template: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Load a checkpoint into ``template``'s structure.

    ``shardings`` (optional pytree of NamedSharding, possibly for a
    DIFFERENT mesh than the one that saved) re-shards each leaf on load —
    the elastic re-mesh path.  Returns (tree, metadata).
    """
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    path = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "arrays.npz"), "rb") as f:
        payload = f.read()
    digest = hashlib.sha256(payload).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {path} payload hash mismatch "
                      f"({digest[:12]} != {manifest['sha256'][:12]})")
    arrays = np.load(io.BytesIO(payload))

    named = _flatten_with_names(template)
    leaves_out = {}
    for k, ref in named.items():
        if k not in arrays:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        a = arrays[k]
        saved_dtype = manifest["leaves"][k]["dtype"]
        if saved_dtype == "bfloat16":
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        if tuple(a.shape) != tuple(jnp.shape(ref)):
            raise ValueError(f"leaf {k!r} shape {a.shape} != template "
                             f"{jnp.shape(ref)}")
        leaves_out[k] = a

    flat_t = jax.tree_util.tree_flatten_with_path(template)
    paths = ["/".join(str(getattr(kk, "key", getattr(kk, "idx", kk)))
                      for kk in p) for p, _ in flat_t[0]]
    ordered = [leaves_out[p] for p in paths]
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "spec"))
        ordered = [jax.device_put(a, s)
                   for a, s in zip(ordered, shard_leaves)]
    else:
        ordered = [jnp.asarray(a) for a in ordered]
    tree = jax.tree_util.tree_unflatten(flat_t[1], ordered)
    return tree, manifest["metadata"]
