"""CGGN — Hessian-free Gauss–Newton optimizer with the JPCG inner solver.

This is the solver↔training bridge that makes Callipepla's contribution a
first-class framework feature: each update solves

    (G + λI) δ = −g ,     G = Jᵀ H_L J   (SPD, matrix-free)

with the paper's Jacobi-preconditioned CG — same three-phase loop, same
on-the-fly termination — where the matvec is a jvp∘vjp through the model
and the mixed-precision scheme is Mix-V3 shifted to the TPU tier: the
GGN matvec runs at the model compute dtype (bf16 "matrix stream"), CG
iterate vectors stay fp32 ("vectors high").

The Jacobi diagonal is a Hutchinson estimate refreshed every
``refresh_precond`` steps; λ follows a Levenberg–Marquardt-style
adaptation on the reduction ratio.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import phases as _phases
from repro.core.gn import estimate_jacobi_diag, flatten_like, make_ggn_matvec
from repro.core.precision import get_scheme

__all__ = ["CGGNConfig", "CGGNState", "cggn_init", "cggn_update",
           "cg_solve_matfree"]


@dataclasses.dataclass(frozen=True)
class CGGNConfig:
    lr: float = 1.0
    damping: float = 1e-2
    cg_iters: int = 16
    cg_tol: float = 1e-8
    probes: int = 4
    scheme: str = "tpu_v3"
    refresh_precond: int = 10
    max_delta_norm: float = 10.0     # trust region: rescale ‖δ‖ above this


class CGGNState(NamedTuple):
    step: jax.Array
    key: jax.Array
    diag: jax.Array          # cached Jacobi estimate (flat param space)


def cg_solve_matfree(matvec, diag, b, *, tol: float, maxiter: int,
                     scheme) -> jax.Array:
    """Traceable JPCG solve (the inner loop of a jitted train step)."""
    scheme = get_scheme(scheme)
    x0 = jnp.zeros_like(b)
    st = _phases.init_state(matvec, diag, b, x0, maxiter=maxiter,
                            scheme=scheme, with_trace=False)
    st = _phases.jpcg_loop(matvec, diag, st, tol=tol, maxiter=maxiter,
                           scheme=scheme)
    return st.x


def cggn_init(params, key: jax.Array) -> CGGNState:
    flat, _, _ = flatten_like(params)
    return CGGNState(step=jnp.zeros((), jnp.int32), key=key,
                     diag=jnp.ones_like(flat.astype(jnp.float32)))


def cggn_update(params, state: CGGNState, *, loss_logits_fn, logits_fn,
                loss_value_and_grad, cfg: CGGNConfig):
    """One CGGN step.

    ``loss_value_and_grad(params) -> (loss, grads)`` — the usual backward;
    ``logits_fn(params) -> logits`` and ``loss_logits_fn(logits) -> scalar``
    define the GGN factorization on the same batch.
    Returns (new_params, new_state, metrics).
    """
    scheme = get_scheme(cfg.scheme)
    loss, grads = loss_value_and_grad(params)
    gflat, ravel, unravel = flatten_like(grads)
    gflat = gflat.astype(scheme.vector_dtype)

    matvec_tree, n = make_ggn_matvec(loss_logits_fn, logits_fn, params,
                                     damping=cfg.damping)

    def matvec(v):
        return matvec_tree(v.astype(scheme.spmv_in_dtype)).astype(
            scheme.vector_dtype)

    key, sub = jax.random.split(state.key)
    refresh = (state.step % cfg.refresh_precond) == 0
    diag_new = jax.lax.cond(
        refresh,
        lambda: estimate_jacobi_diag(matvec, n, sub, probes=cfg.probes,
                                     damping=cfg.damping).astype(jnp.float32),
        lambda: state.diag)

    delta = cg_solve_matfree(matvec, diag_new.astype(scheme.vector_dtype),
                             -gflat, tol=cfg.cg_tol, maxiter=cfg.cg_iters,
                             scheme=scheme)
    # trust region: GN steps on non-quadratic losses can overshoot badly;
    # rescale to max_delta_norm (standard Hessian-free practice)
    dnorm = jnp.linalg.norm(delta.astype(jnp.float32))
    scale = jnp.minimum(1.0, cfg.max_delta_norm / jnp.maximum(dnorm, 1e-9))
    delta = delta * scale.astype(delta.dtype)

    theta, _, unravel_p = flatten_like(params)
    new_params = unravel_p(theta + cfg.lr * delta.astype(theta.dtype))
    metrics = {"loss": loss,
               "delta_norm": jnp.linalg.norm(delta.astype(jnp.float32)),
               "grad_norm": jnp.linalg.norm(gflat.astype(jnp.float32))}
    return new_params, CGGNState(step=state.step + 1, key=key,
                                 diag=diag_new), metrics
