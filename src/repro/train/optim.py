"""Optimizers — AdamW (+ bf16 state compression) and schedules.

``state_dtype="bfloat16"`` stores the Adam moments one precision tier
below the fp32 iterate — the paper's Mix-V3 principle ("store the operator
stream low, keep the iterate high") applied beyond the paper to optimizer
state: halves optimizer HBM traffic/footprint; update math still runs in
fp32 (cast in registers, like the kernel's in-register FP32→FP64 cast).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "bfloat16"    # moment storage (beyond-paper Mix-V3)


class AdamWState(NamedTuple):
    step: jax.Array
    m: object                        # pytree like params
    v: object


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), gn


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr: jax.Array):
    """One AdamW step; moments stored at cfg.state_dtype, math in fp32."""
    if cfg.grad_clip:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                              # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
