"""Jitted wrappers around the Pallas kernels — the `backend="pallas"` path.

* :func:`ell_operator_pallas` (alias ``bell_operator_pallas``) wraps a
  sparse matrix as an operator whose ``matvec`` is the
  :mod:`repro.kernels.spmv` kernel (banked-ELLPACK, mixed precision).
* :func:`make_phase_ops` returns the fused phase-2/phase-3/dot kernels in
  the signature :func:`repro.core.phases.jpcg_loop` consumes, so the whole
  JPCG loop body runs as three Pallas kernels per iteration — the paper's
  three phases, one kernel each.

``interpret`` defaults to "not on TPU": kernels execute via the Pallas
interpreter on CPU (correctness) and lower to Mosaic on TPU (performance).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import PrecisionScheme, get_scheme
from repro.kernels.dot import dot_pallas, dot3_pallas
from repro.kernels.fused_phase import phase2_pallas, phase3_pallas
from repro.kernels.spmv import spmv_pallas
from repro.sparse.csr import CSRMatrix
from repro.sparse.ellpack import EllpackMatrix, csr_to_ellpack

__all__ = ["PallasEllOperator", "ell_operator_pallas", "bell_operator_pallas",
           "make_phase_ops", "default_interpret"]


def default_interpret() -> bool:
    """Interpret unless running on a real TPU."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class PallasEllOperator:
    """ELLPACK matrix whose matvec is the Pallas SpMV kernel."""

    tile_cols: jax.Array   # int32[B, T]
    vals: jax.Array        # matrix_dtype[B, T, E, R]
    local_cols: jax.Array  # int32[B, T, E, R]
    diag: jax.Array        # vector_dtype[n]
    n: int
    block_rows: int
    col_tile: int
    padded_cols: int
    scheme: PrecisionScheme
    nnz: int
    interpret: bool

    @classmethod
    def from_ellpack(cls, m: EllpackMatrix, scheme, diag,
                     interpret: bool | None = None) -> "PallasEllOperator":
        scheme = get_scheme(scheme)
        if interpret is None:
            interpret = default_interpret()
        return cls(
            tile_cols=jnp.asarray(m.tile_cols),
            vals=jnp.asarray(m.vals).astype(scheme.matrix_dtype),
            local_cols=jnp.asarray(m.local_cols),
            diag=jnp.asarray(diag).astype(scheme.vector_dtype),
            n=m.shape[0], block_rows=m.block_rows, col_tile=m.col_tile,
            padded_cols=m.padded_cols, scheme=scheme, nnz=m.nnz,
            interpret=interpret)

    def matvec(self, x: jax.Array) -> jax.Array:
        x_pad = jnp.zeros(self.padded_cols, x.dtype).at[: self.n].set(x)
        x_tiles = x_pad.reshape(-1, self.col_tile)
        y = spmv_pallas(self.tile_cols, self.vals, self.local_cols, x_tiles,
                        scheme=self.scheme, interpret=self.interpret)
        return y.reshape(-1)[: self.n].astype(self.scheme.vector_dtype)

    def flops_per_matvec(self) -> int:
        return 2 * self.nnz


jax.tree_util.register_dataclass(
    PallasEllOperator,
    data_fields=["tile_cols", "vals", "local_cols", "diag"],
    meta_fields=["n", "block_rows", "col_tile", "padded_cols", "scheme",
                 "nnz", "interpret"])


def ell_operator_pallas(a, scheme, *, diag=None, block_rows: int = 256,
                        col_tile: int = 512,
                        interpret: bool | None = None) -> PallasEllOperator:
    """Coerce CSR / EllpackMatrix to a Pallas-backed operator."""
    scheme = get_scheme(scheme)
    if isinstance(a, PallasEllOperator):
        return a
    if isinstance(a, CSRMatrix):
        d = a.diagonal() if diag is None else diag
        m = csr_to_ellpack(a, block_rows=block_rows, col_tile=col_tile)
        return PallasEllOperator.from_ellpack(m, scheme, d, interpret)
    if isinstance(a, EllpackMatrix):
        if diag is None:
            raise ValueError("EllpackMatrix input requires an explicit diag")
        return PallasEllOperator.from_ellpack(a, scheme, diag, interpret)
    arr = np.asarray(a)
    if arr.ndim == 2 and arr.shape[0] == arr.shape[1]:
        from repro.sparse.csr import csr_from_coo
        rows, cols = np.nonzero(arr)
        csr = csr_from_coo(rows, cols, arr[rows, cols], arr.shape)
        return ell_operator_pallas(csr, scheme, diag=diag,
                                   block_rows=block_rows, col_tile=col_tile,
                                   interpret=interpret)
    raise TypeError(f"cannot build a Pallas operator from {type(a)}")


#: cg.py historical alias.
bell_operator_pallas = ell_operator_pallas


def make_phase_ops(interpret: bool | None = None):
    """Phase-op triple for :func:`repro.core.phases.jpcg_loop`.

    Returns ``(dot, phase2, phase3)`` where
    ``dot(a, b) -> scalar``, ``phase2(alpha, r, ap, diag) -> (r', [rr, rz])``
    and ``phase3(alpha, beta, r', diag, p, x) -> (p', x')`` — each one a
    single fused Pallas kernel.
    """
    if interpret is None:
        interpret = default_interpret()

    def dot(a, b):
        return dot_pallas(a, b, acc_dtype=a.dtype, interpret=interpret)

    def phase2(alpha, r, ap, diag):
        return phase2_pallas(alpha, r, ap, diag, interpret=interpret)

    def phase3(alpha, beta, r_new, diag, p, x):
        return phase3_pallas(alpha, beta, r_new, diag, p, x,
                             interpret=interpret)

    return dot, phase2, phase3


def make_dot3(interpret: bool | None = None):
    """Fused triple-dot for the pipelined solver's single reduction."""
    if interpret is None:
        interpret = default_interpret()

    def dot3(r, u, w):
        return dot3_pallas(r, u, w, acc_dtype=r.dtype, interpret=interpret)

    return dot3
