"""Flash attention (forward) — Pallas TPU kernel, online softmax.

The §Perf logs (EXPERIMENTS.md) show every train/prefill cell memory-bound
with the score-tensor HBM round trips as the largest removable term: the
XLA path materializes [S, T] scores + softmax intermediates per head.
This kernel streams K/V blocks past a VMEM-resident Q block with running
(m, l) statistics — scores never leave VMEM, exactly the paper's VSR
principle (intermediates stay on-chip; only true inputs/outputs touch
HBM) applied to attention.

Layout: head-major [BH, S, D] (matches the decode cache layout).  Causal
and sliding-window masks are positional; fully-masked K blocks are
skipped via ``pl.when`` on the block index (the causal half and the
out-of-window band cost no MXU work).

Validated under ``interpret=True`` vs :func:`repro.kernels.ref.mha_ref`
(tests/test_flash_attn.py); block sizes default to MXU/VMEM-aligned
(128, 512) for D ≤ 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["flash_attention"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, bq, bk, n_kblocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # skip K blocks that the causal/window mask fully excludes
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale       # [bq, bk]
        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= jk <= iq
        if window is not None:
            mask &= jk > iq - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                    # [bq, bk]
        corr = jnp.exp(m_prev - m_new)            # [bq, 1]
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == n_kblocks - 1)
    def _final():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30))[None].astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q [BH, S, D], k/v [BH, T, D] -> [BH, S, D].

    Scores and softmax statistics never leave VMEM; HBM traffic is the
    q/k/v reads + output write.  ``window``: sliding-window width.
    """
    bh, s, d = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, "pad seq to block multiples"
    n_kb = t // bk
    scale = d ** -0.5

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, n_kblocks=n_kb)
    return pl.pallas_call(
        kern,
        grid=(bh, s // bq, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
