"""Pallas TPU kernels (validated interpret=True on CPU).

* :mod:`repro.kernels.spmv`        — banked-ELLPACK mixed-precision SpMV (M1);
* :mod:`repro.kernels.dot`         — two-phase lane-parallel dot / fused dot3;
* :mod:`repro.kernels.fused_phase` — the VSR phase-2/phase-3 fused kernels;
* :mod:`repro.kernels.flash_attn`  — online-softmax attention (the §Perf
  "next lever" for the memory-bound train/prefill cells: scores stay in
  VMEM — VSR applied to attention);
* :mod:`repro.kernels.ops`         — jitted wrappers (`backend="pallas"`);
* :mod:`repro.kernels.ref`         — pure-jnp oracles for every kernel.
"""
from repro.kernels.flash_attn import flash_attention
from repro.kernels.ops import (bell_operator_pallas, ell_operator_pallas,
                               make_phase_ops, PallasEllOperator)

__all__ = ["bell_operator_pallas", "ell_operator_pallas", "make_phase_ops",
           "PallasEllOperator", "flash_attention"]
