"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors the corresponding kernel's *dataflow* (same
accumulation order and the same precision-scheme casts) so that
``assert_allclose(kernel, ref)`` sweeps in ``tests/test_kernels.py`` are
meaningful at tight tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionScheme

__all__ = ["spmv_ref", "dot_ref", "dot3_ref", "phase2_ref", "phase3_ref"]


def spmv_ref(tile_cols: jax.Array, vals: jax.Array, local_cols: jax.Array,
             x_tiles: jax.Array, *, scheme: PrecisionScheme) -> jax.Array:
    """ELLPACK SpMV oracle.

    tile_cols int32[B, T]; vals md[B, T, E, R]; local_cols int32[B, T, E, R];
    x_tiles [n_col_tiles, C] at spmv_in_dtype.  Returns acc_dtype[B, R].
    """
    acc = scheme.spmv_acc_dtype
    B, T, E, R = vals.shape
    x_in = x_tiles.astype(scheme.spmv_in_dtype)
    xt = x_in[tile_cols]                               # [B, T, C]
    xg = jnp.take_along_axis(
        xt[:, :, None, :].astype(acc),
        local_cols.astype(jnp.int32),
        axis=-1) if False else jnp.take_along_axis(
        jnp.broadcast_to(xt[:, :, None, :], (B, T, E, xt.shape[-1])),
        local_cols, axis=-1)                           # [B, T, E, R]
    prod = vals.astype(acc) * xg.astype(acc)
    return jnp.sum(prod, axis=(1, 2)).astype(acc)      # [B, R]


def dot_ref(a: jax.Array, b: jax.Array, acc_dtype=jnp.float32) -> jax.Array:
    """Dot oracle: sum(a*b) at acc_dtype (kernel accumulation order is
    blockwise; fp addition reassociation is covered by test tolerances)."""
    return jnp.sum(a.astype(acc_dtype) * b.astype(acc_dtype))


def dot3_ref(r: jax.Array, u: jax.Array, w: jax.Array,
             acc_dtype=jnp.float32) -> jax.Array:
    """Fused triple-dot oracle: [r·u, w·u, r·r] in one pass (pipelined CG)."""
    r = r.astype(acc_dtype)
    u = u.astype(acc_dtype)
    w = w.astype(acc_dtype)
    return jnp.stack([jnp.sum(r * u), jnp.sum(w * u), jnp.sum(r * r)])


def phase2_ref(alpha: jax.Array, r: jax.Array, ap: jax.Array,
               diag: jax.Array):
    """Phase-2 VSR oracle: r' = r − α·ap; rr = r'·r'; z = r'/M (never
    stored); rz = r'·z.  Returns (r_new, jnp.stack([rr, rz]))."""
    r_new = r - alpha * ap
    z = r_new / diag
    rr = jnp.sum(r_new * r_new)
    rz = jnp.sum(r_new * z)
    return r_new, jnp.stack([rr, rz])


def phase3_ref(alpha: jax.Array, beta: jax.Array, r_new: jax.Array,
               diag: jax.Array, p: jax.Array, x: jax.Array):
    """Phase-3 VSR oracle: z = r'/M recomputed (§5.3), p' = z + β·p,
    x' = x + α·p.  Returns (p_new, x_new)."""
    z = r_new / diag
    p_new = z + beta * p
    x_new = x + alpha * p
    return p_new, x_new


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, window=None) -> jax.Array:
    """Flash-attention oracle: plain masked softmax attention, head-major
    [BH, S, D] inputs, fp32 softmax."""
    s, t = q.shape[1], k.shape[1]
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    scores = jnp.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
