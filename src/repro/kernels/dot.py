"""Dot-product kernels — the M2/M6/M8 modules, and the pipelined-CG dot3.

Paper §4.2 footnote 1: the FPGA dot modules run two phases — Phase I
multiply-accumulates into a *cyclic delay buffer* at II=1 (the FP-add
latency L=5 is hidden by L independent partial sums), Phase II collapses
the buffer with a fixed 5·L-cycle pass.

The TPU spelling of the same idea: Phase I accumulates an ``[8, LANES]``
VMEM tile of partial sums — every VPU lane owns one partial, so the serial
FP-add dependence is broken exactly as the delay buffer breaks it — and
Phase II is a log-depth tree reduction of the tile on the final grid step.

``dot3`` fuses the three reductions of pipelined CG (γ = r·u, δ = w·u,
‖r‖²) into ONE sweep: r, u, w stream through VMEM once and three
accumulator tiles update per step.  At pod scale this is what turns three
all-reduces into one (see repro/core/pipelined.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["dot_pallas", "dot3_pallas", "DOT_BLOCK"]

#: rows × lanes of one grid-step tile (8 sublanes × 512 lanes of fp32).
DOT_BLOCK = (8, 512)


def _pad2d(v: jax.Array, dtype) -> jax.Array:
    """Zero-pad a vector to [nb, 8, L] grid-of-tiles layout."""
    rows, lanes = DOT_BLOCK
    chunk = rows * lanes
    n = v.shape[0]
    nb = max(1, -(-n // chunk))
    vp = jnp.zeros(nb * chunk, dtype).at[:n].set(v.astype(dtype))
    return vp.reshape(nb, rows, lanes)


def _dot_kernel(a_ref, b_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += a_ref[0] * b_ref[0]          # Phase I: lane partials

    @pl.when(i == pl.num_programs(0) - 1)
    def _reduce():                               # Phase II: tree reduce
        o_ref[0, 0] = jnp.sum(acc_ref[...])


@functools.partial(jax.jit, static_argnames=("acc_dtype", "interpret"))
def dot_pallas(a: jax.Array, b: jax.Array, *, acc_dtype=jnp.float32,
               interpret: bool = False) -> jax.Array:
    """⟨a, b⟩ with lane-parallel partial sums.  Returns a 0-d scalar."""
    rows, lanes = DOT_BLOCK
    ap = _pad2d(a, acc_dtype)
    bp = _pad2d(b, acc_dtype)
    nb = ap.shape[0]
    out = pl.pallas_call(
        _dot_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), acc_dtype),
        scratch_shapes=[pltpu.VMEM((rows, lanes), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(ap, bp)
    return out[0, 0]


def _dot3_kernel(r_ref, u_ref, w_ref, o_ref, accru_ref, accwu_ref, accrr_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        accru_ref[...] = jnp.zeros_like(accru_ref)
        accwu_ref[...] = jnp.zeros_like(accwu_ref)
        accrr_ref[...] = jnp.zeros_like(accrr_ref)

    r = r_ref[0]
    u = u_ref[0]
    w = w_ref[0]
    accru_ref[...] += r * u
    accwu_ref[...] += w * u
    accrr_ref[...] += r * r

    @pl.when(i == pl.num_programs(0) - 1)
    def _reduce():
        o_ref[0, 0] = jnp.sum(accru_ref[...])
        o_ref[0, 1] = jnp.sum(accwu_ref[...])
        o_ref[0, 2] = jnp.sum(accrr_ref[...])


@functools.partial(jax.jit, static_argnames=("acc_dtype", "interpret"))
def dot3_pallas(r: jax.Array, u: jax.Array, w: jax.Array, *,
                acc_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """Fused [r·u, w·u, r·r] in one sweep over r, u, w.  Returns shape (3,)."""
    rows, lanes = DOT_BLOCK
    rp = _pad2d(r, acc_dtype)
    up = _pad2d(u, acc_dtype)
    wp = _pad2d(w, acc_dtype)
    nb = rp.shape[0]
    out = pl.pallas_call(
        _dot3_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))] * 3,
        out_specs=pl.BlockSpec((1, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 3), acc_dtype),
        scratch_shapes=[pltpu.VMEM((rows, lanes), acc_dtype)] * 3,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(rp, up, wp)
    return out[0]
