"""Fused VSR phase kernels — the paper's §5 streaming reuse, made explicit.

One kernel per JPCG phase: every vector the phase touches streams through
VMEM exactly once, all consumer "modules" of that phase read it from the
same resident tile, and intermediates that the schedule marks
``never_stored`` (``z``) exist only inside the kernel.  FIFO depth ≈ the
implicit double buffer Pallas allocates per BlockSpec operand.

* **phase2**: M4 (r' = r − α·ap), M8 (rr, hoisted for early termination),
  M5 (z = r'/M, never stored), M6 (rz) — reads r, ap, M once; writes r'
  once (min-traffic policy: the store the FPGA's FSM port wiring forbids,
  legal here); emits the two scalars in lane-parallel accumulators like
  :mod:`repro.kernels.dot`.
* **phase3**: M5-recompute (z = r'/M, §5.3), M7 (p' = z + β·p), M3
  (x' = x + α·p) — reads r', M, p, x once; writes p', x' once; the ``p``
  stream is shared by M7 and M3 (one read, two consumers — the VecCtrl-p
  duplication of paper Fig. 6).

HBM traffic for the fused loop body (per element, vector streams only):
phase1 SpMV reads + ap write, phase2 3R+1W, phase3 4R+2W — the 13-access
min-traffic schedule computed by :mod:`repro.core.vsr`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.kernels.dot import DOT_BLOCK, _pad2d

__all__ = ["phase2_pallas", "phase3_pallas"]


def _phase2_kernel(alpha_ref, r_ref, ap_ref, m_ref, rnew_ref, s_ref,
                   accrr_ref, accrz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        accrr_ref[...] = jnp.zeros_like(accrr_ref)
        accrz_ref[...] = jnp.zeros_like(accrz_ref)

    alpha = alpha_ref[0, 0]
    r_new = r_ref[0] - alpha * ap_ref[0]     # M4
    rnew_ref[...] = r_new[None]              # single store of record
    z = r_new / m_ref[0]                     # M5 — never leaves VMEM
    accrr_ref[...] += r_new * r_new          # M8 (hoisted)
    accrz_ref[...] += r_new * z              # M6

    @pl.when(i == pl.num_programs(0) - 1)
    def _reduce():
        s_ref[0, 0] = jnp.sum(accrr_ref[...])
        s_ref[0, 1] = jnp.sum(accrz_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def phase2_pallas(alpha: jax.Array, r: jax.Array, ap: jax.Array,
                  diag: jax.Array, *, interpret: bool = False):
    """Fused phase 2.  Returns (r_new [n], scalars [rr, rz])."""
    rows, lanes = DOT_BLOCK
    n = r.shape[0]
    dt = r.dtype
    rp = _pad2d(r, dt)
    app = _pad2d(ap, dt)
    # pad M with ones: padded lanes compute z = 0/1 = 0, contributing 0.
    chunk = rows * lanes
    nb = rp.shape[0]
    mp = jnp.ones(nb * chunk, dt).at[:n].set(diag.astype(dt)).reshape(
        nb, rows, lanes)
    a2 = jnp.asarray(alpha, dt).reshape(1, 1)

    r_new, s = pl.pallas_call(
        _phase2_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, rows, lanes), dt),
                   jax.ShapeDtypeStruct((1, 2), dt)],
        scratch_shapes=[pltpu.VMEM((rows, lanes), dt)] * 2,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(a2, rp, app, mp)
    return r_new.reshape(-1)[:n], s[0]


def _phase3_kernel(ab_ref, rnew_ref, m_ref, p_ref, x_ref, pnew_ref, xnew_ref):
    alpha = ab_ref[0, 0]
    beta = ab_ref[0, 1]
    p = p_ref[0]                              # ONE read, two consumers
    z = rnew_ref[0] / m_ref[0]                # M5 recomputed (§5.3)
    pnew_ref[...] = (z + beta * p)[None]      # M7
    xnew_ref[...] = (x_ref[0] + alpha * p)[None]   # M3


@functools.partial(jax.jit, static_argnames=("interpret",))
def phase3_pallas(alpha: jax.Array, beta: jax.Array, r_new: jax.Array,
                  diag: jax.Array, p: jax.Array, x: jax.Array, *,
                  interpret: bool = False):
    """Fused phase 3.  Returns (p_new [n], x_new [n])."""
    rows, lanes = DOT_BLOCK
    n = r_new.shape[0]
    dt = r_new.dtype
    rp = _pad2d(r_new, dt)
    pp = _pad2d(p, dt)
    xp = _pad2d(x, dt)
    chunk = rows * lanes
    nb = rp.shape[0]
    mp = jnp.ones(nb * chunk, dt).at[:n].set(diag.astype(dt)).reshape(
        nb, rows, lanes)
    ab = jnp.stack([jnp.asarray(alpha, dt),
                    jnp.asarray(beta, dt)]).reshape(1, 2)

    p_new, x_new = pl.pallas_call(
        _phase3_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, rows, lanes), dt),
                   jax.ShapeDtypeStruct((nb, rows, lanes), dt)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(ab, rp, mp, pp, xp)
    return p_new.reshape(-1)[:n], x_new.reshape(-1)[:n]
