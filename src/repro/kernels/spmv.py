"""Mixed-precision banked-ELLPACK SpMV — the M1 module as a Pallas kernel.

TPU adaptation of the paper's Serpens-based SpMV (§6, Fig. 8):

  ==============================  =========================================
  Callipepla (U280)               this kernel (TPU v5e)
  ==============================  =========================================
  16 HBM channels × 8 PEs         grid dimension 0 over row blocks
                                  (``dimension_semantics="parallel"``)
  BRAM X-memory (4K deep)         x col-tile resident in VMEM; fetched by
                                  the BlockSpec ``index_map`` driven by the
                                  scalar-prefetched ``tile_cols`` stream —
                                  the Type-III memory-instruction analogue
  URAM Y-memory (24K deep)        y row-block accumulator in VMEM, revision
                                  over grid dim 1 (slabs), written once
  64-bit packed nonzero           slot-major ELLPACK entry: value at
  (14b col, 18b row, fp32 val)    ``matrix_dtype`` + int16-capable *local*
                                  col index; the row is the lane id
  FP32→FP64 cast + FMA            ``vals.astype(acc) * x.astype(acc)`` —
                                  the Mix-V3 cast happens in-register
  ==============================  =========================================

VMEM budget per grid step (defaults R=256, C=512, E≤32, fp32):
x tile 2 KB + vals/lcols 2·E·R·4 B ≤ 256 KB + y 1 KB — far under the 16 MB
v5e VMEM even with double buffering; block shapes are lane(128)/sublane(8)
aligned.

The gather ``x_tile[local_cols]`` is a dynamic VMEM gather (Mosaic
``DynamicGatherOp``); on CPU we validate under ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.core.precision import PrecisionScheme

__all__ = ["spmv_pallas", "spmv_pallas_batched", "spmv_pallas_sell"]


def _spmv_kernel(tile_cols_ref, vals_ref, lcols_ref, x_ref, y_ref, *,
                 acc_dtype):
    """One (row-block i, slab t) grid step: y[i] += Σ_e vals[i,t,e,:] ⊙
    x_tile[lcols[i,t,e,:]]."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x_tile = x_ref[0]                       # [C] spmv_in_dtype
    vals = vals_ref[0, 0]                   # [E, R] matrix_dtype
    lcols = lcols_ref[0, 0]                 # [E, R] int32
    xg = jnp.take(x_tile, lcols.reshape(-1), axis=0,
                  indices_are_sorted=False, unique_indices=False,
                  mode="clip").reshape(vals.shape)
    prod = vals.astype(acc_dtype) * xg.astype(acc_dtype)
    y_ref[...] += jnp.sum(prod, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("scheme", "interpret"))
def spmv_pallas(tile_cols: jax.Array, vals: jax.Array, local_cols: jax.Array,
                x_tiles: jax.Array, *, scheme: PrecisionScheme,
                interpret: bool = False) -> jax.Array:
    """Banked-ELLPACK SpMV.

    tile_cols int32[B, T] — scalar-prefetched memory-instruction stream;
    vals scheme.matrix_dtype[B, T, E, R]; local_cols int32[B, T, E, R];
    x_tiles [n_col_tiles, C] (cast to ``scheme.spmv_in_dtype`` here — the
    Mix-V1/V2 information loss point).  Returns acc_dtype[B, R].
    """
    B, T, E, R = vals.shape
    C = x_tiles.shape[-1]
    acc = scheme.spmv_acc_dtype
    x_in = x_tiles.astype(scheme.spmv_in_dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, 1, E, R), lambda i, t, tc: (i, t, 0, 0)),
            pl.BlockSpec((1, 1, E, R), lambda i, t, tc: (i, t, 0, 0)),
            pl.BlockSpec((1, C), lambda i, t, tc: (tc[i, t], 0)),
        ],
        out_specs=pl.BlockSpec((1, R), lambda i, t, tc: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_spmv_kernel, acc_dtype=acc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R), acc),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_cols, vals, local_cols, x_in)


def _spmv_kernel_batched(tile_cols_ref, vals_ref, lcols_ref, x_ref, y_ref, *,
                         acc_dtype):
    """One (system g, row-block i, slab t) grid step of the batched SpMV."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x_tile = x_ref[0, 0]                    # [C] spmv_in_dtype
    vals = vals_ref[0, 0, 0]                # [E, R] matrix_dtype
    lcols = lcols_ref[0, 0, 0]              # [E, R] int32
    xg = jnp.take(x_tile, lcols.reshape(-1), axis=0,
                  indices_are_sorted=False, unique_indices=False,
                  mode="clip").reshape(vals.shape)
    prod = vals.astype(acc_dtype) * xg.astype(acc_dtype)
    y_ref[...] += jnp.sum(prod, axis=0)[None, None, :]


@functools.partial(jax.jit, static_argnames=("scheme", "interpret"))
def spmv_pallas_batched(tile_cols: jax.Array, vals: jax.Array,
                        local_cols: jax.Array, x_tiles: jax.Array, *,
                        scheme: PrecisionScheme,
                        interpret: bool = False) -> jax.Array:
    """Batch-of-systems banked-ELLPACK SpMV — one kernel, G independent A·x.

    The multi-system spelling of :func:`spmv_pallas`: a leading *batch*
    grid dimension walks the G stacked systems, so one Mosaic executable
    serves the whole batch (the batched engine's per-iteration M1).

    tile_cols int32[G, B, T] — per-system scalar-prefetched memory-
    instruction streams; vals scheme.matrix_dtype[G, B, T, E, R];
    local_cols int32[G, B, T, E, R]; x_tiles [G, n_col_tiles, C].
    Returns acc_dtype[G, B, R].
    """
    G, B, T, E, R = vals.shape
    C = x_tiles.shape[-1]
    acc = scheme.spmv_acc_dtype
    x_in = x_tiles.astype(scheme.spmv_in_dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, B, T),
        in_specs=[
            pl.BlockSpec((1, 1, 1, E, R), lambda g, i, t, tc: (g, i, t, 0, 0)),
            pl.BlockSpec((1, 1, 1, E, R), lambda g, i, t, tc: (g, i, t, 0, 0)),
            pl.BlockSpec((1, 1, C), lambda g, i, t, tc: (g, tc[g, i, t], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R), lambda g, i, t, tc: (g, i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_spmv_kernel_batched, acc_dtype=acc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, B, R), acc),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile_cols, vals, local_cols, x_in)


def _spmv_sell_kernel(cols_ref, vals_ref, x_ref, y_ref, *, acc_dtype):
    """One system g of one SELL width group: y_sorted[rows] = tree-sum
    over the group's w slots of vals ⊙ x[cols]."""
    from repro.core.batch import rounded_products, tree_sum
    x_lane = x_ref[0]                       # [n_pad] spmv_in_dtype
    c = cols_ref[0]                         # [w, rows] int16/int32
    v = vals_ref[0]                         # [w, rows] matrix_dtype
    xg = jnp.take(x_lane, c.reshape(-1).astype(jnp.int32), axis=0,
                  indices_are_sorted=False, unique_indices=False,
                  mode="clip").reshape(v.shape)
    prod = rounded_products(v, xg, acc_dtype)
    y_ref[...] = tree_sum(prod, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("groups", "scheme",
                                             "interpret"))
def spmv_pallas_sell(cols: jax.Array, vals: jax.Array, x: jax.Array, *,
                     groups, scheme: PrecisionScheme,
                     interpret: bool = False) -> jax.Array:
    """Batched SELL-C-σ SpMV — one Pallas launch per static width group.

    ``cols/vals`` are the flat slot-major ``[G, L]`` arrays of
    :func:`repro.sparse.stacking.stack_sell` (values at the scheme's
    at-rest ``matrix_dtype``, indices int16/int32), ``x`` is
    ``[G, n_pad]``, ``groups`` the static ``(rows, width)`` signature.
    Each group is a dense ``[w, rows]`` rectangle whose row reduction is
    the same deterministic halving tree as the XLA path
    (:func:`repro.core.batch.tree_sum`), so under ``interpret=True`` the
    result is bit-identical to :func:`repro.core.batch
    .batched_matvec_sell` before the un-permutation.

    Returns ``acc_dtype[G, n_pad]`` in **sorted** row order — the caller
    applies the stacked ``iperm`` (and the vector-dtype cast).
    """
    G, n_pad = x.shape
    acc = scheme.spmv_acc_dtype
    x_in = x.astype(scheme.spmv_in_dtype)
    parts, off = [], 0
    for rows, w in groups:
        if w == 0:
            parts.append(jnp.zeros((G, rows), acc))
            continue
        c = cols[:, off:off + rows * w].reshape(G, w, rows)
        v = vals[:, off:off + rows * w].reshape(G, w, rows)
        y = pl.pallas_call(
            functools.partial(_spmv_sell_kernel, acc_dtype=acc),
            grid=(G,),
            in_specs=[
                pl.BlockSpec((1, w, rows), lambda g: (g, 0, 0)),
                pl.BlockSpec((1, w, rows), lambda g: (g, 0, 0)),
                pl.BlockSpec((1, n_pad), lambda g: (g, 0)),
            ],
            out_specs=pl.BlockSpec((1, rows), lambda g: (g, 0)),
            out_shape=jax.ShapeDtypeStruct((G, rows), acc),
            compiler_params=CompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret,
        )(c, v, x_in)
        parts.append(y)
        off += rows * w
    return jnp.concatenate(parts, axis=1)
