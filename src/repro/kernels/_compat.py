"""Version compatibility for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` upstream;
kernels import the name from here so one repo runs on both sides of the
rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:  # pre-rename JAX
    CompilerParams = pltpu.TPUCompilerParams
