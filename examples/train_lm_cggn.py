"""End-to-end LM training driver — AdamW first, then the CGGN optimizer
whose inner loop IS the paper's JPCG solver (matrix-free Gauss–Newton).

Trains a ~100M-param gemma3-family model for a few hundred steps on the
synthetic Markov stream; loss drops visibly under both optimizers.

    PYTHONPATH=src python examples/train_lm_cggn.py [--steps 200]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import count_params, forward_logits, init_params
from repro.train import (AdamWConfig, CGGNConfig, DataConfig, SyntheticLM,
                         Trainer, TrainerConfig, adamw_init, cggn_init,
                         cggn_update, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--cggn-steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--size", choices=["100m", "25m"], default="100m",
                    help="~100M is the deliverable scale (takes a while "
                         "on CPU); 25m for a quick demo")
    args = ap.parse_args()

    # gemma3-family config reduced from the 1B.
    if args.size == "100m":
        cfg = dataclasses.replace(
            get_config("gemma3-1b"), name="gemma3-100m", n_layers=6,
            d_model=512, n_heads=8, n_kv_heads=2, d_ff=1536, head_dim=64,
            vocab=8192, sliding_window=128, dtype="float32", remat=False)
    else:
        cfg = dataclasses.replace(
            get_config("gemma3-1b"), name="gemma3-25m", n_layers=4,
            d_model=256, n_heads=4, n_kv_heads=1, d_ff=768, head_dim=64,
            vocab=4096, sliding_window=128, dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}, {count_params(params) / 1e6:.1f}M params")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                  global_batch=args.batch, source="markov"))

    # ---------------- phase 1: AdamW ----------------
    opt = AdamWConfig(lr=3e-3)
    step_fn = make_train_step(cfg, opt=opt, microbatches=2)
    trainer = Trainer(cfg, data, step_fn, params, adamw_init(params, opt),
                      TrainerConfig(total_steps=args.steps, ckpt_every=100,
                                    ckpt_dir="/tmp/ex_cggn_ckpt",
                                    log_every=25))
    log = trainer.run()
    print(f"AdamW: loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")

    # ---------------- phase 2: CGGN (JPCG inner solver) ----------------
    params = trainer.params
    ccfg = CGGNConfig(lr=0.5, damping=0.1, cg_iters=10, scheme="tpu_fp32",
                      max_delta_norm=2.0)
    state = cggn_init(params, jax.random.PRNGKey(1))
    print(f"\nCGGN fine-tune: each step solves (G+λI)δ=-g with "
          f"{ccfg.cg_iters}-iteration JPCG (scheme={ccfg.scheme})")
    for step in range(args.cggn_steps):
        batch = data.batch_at(10_000 + step)

        def logits_fn(p):
            return forward_logits(p, cfg, batch)

        def loss_logits(lg):
            lse = jax.nn.logsumexp(lg, axis=-1)
            picked = jnp.take_along_axis(
                lg, batch["labels"][..., None], axis=-1)[..., 0]
            return jnp.mean(lse - picked)

        def vag(p):
            return jax.value_and_grad(
                lambda q: loss_logits(logits_fn(q)))(p)

        params, state, m = cggn_update(
            params, state, loss_logits_fn=loss_logits, logits_fn=logits_fn,
            loss_value_and_grad=vag, cfg=ccfg)
        if step % 5 == 0 or step == args.cggn_steps - 1:
            print(f"  cggn step {step:3d}  loss {float(m['loss']):.4f}  "
                  f"|δ| {float(m['delta_norm']):.3f}")


if __name__ == "__main__":
    main()
