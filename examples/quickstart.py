"""Quickstart — solve a linear system with Callipepla-JAX in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)   # faithful FP64 tier on CPU

import numpy as np                                     # noqa: E402

from repro.core.cg import jpcg_solve                   # noqa: E402
from repro.sparse import poisson_2d, csr_spmv          # noqa: E402

# A 2-D Poisson problem (ecology2-class structure, paper Table 3).
A = poisson_2d(64)                 # 4096 × 4096, SPD
print(f"matrix: n={A.shape[0]}, nnz={A.nnz}")

# Paper protocol (§7.1): b = 1⃗, x0 = 0⃗, ‖r‖² < 1e-12, 20k-iteration cap.
res = jpcg_solve(A, scheme="mixed_v3", tol=1e-12, maxiter=20_000)
print(res)

b = np.ones(A.shape[0])
true_resid = np.linalg.norm(csr_spmv(A, np.asarray(res.x)) - b)
print(f"‖A·x − b‖ = {true_resid:.3e}")

# The same solve under the paper's other precision schemes:
for scheme in ("fp64", "mixed_v1"):
    r = jpcg_solve(A, scheme=scheme, tol=1e-12, maxiter=20_000)
    print(f"{scheme:9s}: {r.iterations} iterations, converged={r.converged}")
