"""End-to-end solver tour: schemes × methods × backends + the stream VM.

Reproduces the paper's comparison structure on one problem:
  * default FP64 vs Mix-V1/V2/V3 (Table 1 / Fig. 9),
  * paper-faithful VSR loop vs beyond-paper pipelined CG,
  * XLA backend vs Pallas kernels (interpret mode on CPU),
  * the schedule→program pipeline: VSR schedules compiled to
    stream-ISA programs and executed on the batched VM (§3–5), with the
    19 → 14 → 13 HBM access-count story made concrete per policy.

    PYTHONPATH=src python examples/solve_poisson.py [n_side]
"""
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                     # noqa: E402

from repro.core.cg import jpcg_solve                   # noqa: E402
from repro.core.compile import compile_policy          # noqa: E402
from repro.core.isa import derived_mem_instructions    # noqa: E402
from repro.core.vm import vm_solve                     # noqa: E402
from repro.core.vsr import access_counts               # noqa: E402
from repro.sparse import poisson_2d                    # noqa: E402

n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 48
A = poisson_2d(n_side)
print(f"2-D Poisson, n={A.shape[0]}, nnz={A.nnz}\n")

print("— precision schemes (paper Table 1) —")
for scheme in ("fp64", "mixed_v3", "mixed_v2", "mixed_v1"):
    r = jpcg_solve(A, scheme=scheme, tol=1e-12, maxiter=20_000)
    print(f"  {scheme:9s}: iters={r.iterations:5d} converged={r.converged}")

print("\n— methods (paper VSR vs beyond-paper pipelined) —")
for method in ("vsr", "pipelined"):
    r = jpcg_solve(A, scheme="mixed_v3", method=method, tol=1e-12,
                   maxiter=20_000)
    print(f"  {method:9s}: iters={r.iterations:5d} rr={r.rr:.2e}")

print("\n— backends (XLA vs Pallas kernels, interpret on CPU) —")
for backend in ("xla", "pallas"):
    r = jpcg_solve(A, scheme="mixed_v3", backend=backend, tol=1e-12,
                   maxiter=20_000, block_rows=128, col_tile=256)
    print(f"  {backend:9s}: iters={r.iterations:5d} rr={r.rr:.2e}")

print("\n— schedule → program → batched VM (paper §3–5) —")
c = access_counts()
print(f"  VSR accounting: naive {c['naive']['total']} -> paper "
      f"{c['paper']['total']} -> min-traffic {c['min_traffic']['total']}")

# The same system, solved through the phase-fused production loop and
# through a compiled min-traffic program on the stream VM: identical
# iterate path, two HBM traffic schedules.
ref = jpcg_solve(A, scheme="mixed_v3", tol=1e-12, maxiter=20_000)
print(f"  phase loop  : iters={ref.iterations:5d} rr={ref.rr:.2e}  "
      f"(implicit schedule, XLA-fused)")
for policy in ("paper", "min_traffic"):
    cp = compile_policy(policy)
    mem = derived_mem_instructions(cp.program)
    out = vm_solve(A, program=cp.program, tol=1e-12, maxiter=20_000)
    print(f"  vm[{policy:11s}]: program={cp.length} instrs "
          f"(Type-III: {mem['reads']}R+{mem['writes']}W)  "
          f"iters={out['iterations']} rr={out['rr']:.2e}")

naive = c["naive"]
paper = derived_mem_instructions(compile_policy("paper").program)
mint = derived_mem_instructions(compile_policy("min_traffic").program)
print(f"\n  HBM vector accesses per iteration: naive {naive['total']} "
      f"-> paper VSR {paper['total']} -> min-traffic {mint['total']}")
print(f"  compiled delta vs naive : paper saves "
      f"{naive['total'] - paper['total']}, min-traffic saves "
      f"{naive['total'] - mint['total']} "
      f"(one fewer read than the paper: r' stores straight from phase 2)")

x = np.asarray(out["x"])
print(f"\nsolution norm: {np.linalg.norm(x):.6f}")
