"""End-to-end solver tour: schemes × methods × backends + the stream VM.

Reproduces the paper's comparison structure on one problem:
  * default FP64 vs Mix-V1/V2/V3 (Table 1 / Fig. 9),
  * paper-faithful VSR loop vs beyond-paper pipelined CG,
  * XLA backend vs Pallas kernels (interpret mode on CPU),
  * the stream-centric ISA program executed on the VM (§3–4).

    PYTHONPATH=src python examples/solve_poisson.py [n_side]
"""
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                     # noqa: E402

from repro.core.cg import jpcg_solve                   # noqa: E402
from repro.core.isa import assemble_jpcg, derived_mem_instructions  # noqa: E402
from repro.core.vm import vm_solve                     # noqa: E402
from repro.core.vsr import access_counts               # noqa: E402
from repro.sparse import poisson_2d                    # noqa: E402

n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 48
A = poisson_2d(n_side)
print(f"2-D Poisson, n={A.shape[0]}, nnz={A.nnz}\n")

print("— precision schemes (paper Table 1) —")
for scheme in ("fp64", "mixed_v3", "mixed_v2", "mixed_v1"):
    r = jpcg_solve(A, scheme=scheme, tol=1e-12, maxiter=20_000)
    print(f"  {scheme:9s}: iters={r.iterations:5d} converged={r.converged}")

print("\n— methods (paper VSR vs beyond-paper pipelined) —")
for method in ("vsr", "pipelined"):
    r = jpcg_solve(A, scheme="mixed_v3", method=method, tol=1e-12,
                   maxiter=20_000)
    print(f"  {method:9s}: iters={r.iterations:5d} rr={r.rr:.2e}")

print("\n— backends (XLA vs Pallas kernels, interpret on CPU) —")
for backend in ("xla", "pallas"):
    r = jpcg_solve(A, scheme="mixed_v3", backend=backend, tol=1e-12,
                   maxiter=20_000, block_rows=128, col_tile=256)
    print(f"  {backend:9s}: iters={r.iterations:5d} rr={r.rr:.2e}")

print("\n— stream-centric ISA on the VM (paper §3–4) —")
c = access_counts()
print(f"  VSR accounting: naive {c['naive']['total']} -> paper "
      f"{c['paper']['total']} -> min-traffic {c['min_traffic']['total']}")
for policy in ("paper", "min_traffic"):
    prog, _ = assemble_jpcg(policy)
    mem = derived_mem_instructions(prog)
    out = vm_solve(A, program=prog, tol=1e-12, maxiter=20_000)
    print(f"  {policy:12s}: program={prog.shape[0]} instrs "
          f"(Type-III: {mem['reads']}R+{mem['writes']}W)  "
          f"iters={out['iterations']} rr={out['rr']:.2e}")

x = np.asarray(out["x"])
print(f"\nsolution norm: {np.linalg.norm(x):.6f}")
