"""Batched serving demo — slot engine with ragged request admission.

Runs the mamba2 family (O(1) decode state) and a SWA dense family side by
side, admitting requests mid-flight.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import count_params, init_params
from repro.serve import DecodeEngine, EngineConfig, bytes_per_slot


def demo(arch: str, n_requests: int = 6, max_new: int = 32):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"\n=== {arch} (reduced: {count_params(params) / 1e6:.1f}M) ===")
    print(f"cache bytes/slot @512 ctx: {bytes_per_slot(cfg, 512):,}")

    eng = DecodeEngine(cfg, params, EngineConfig(
        batch_slots=4, max_len=512, temperature=0.7, cache_dtype="float32"))
    rng = np.random.default_rng(0)
    pending = [[int(t) for t in rng.integers(1, cfg.vocab, size=k)]
               for k in rng.integers(4, 12, size=n_requests)]

    t0 = time.monotonic()
    tokens_out = 0
    while pending or eng.active.any():
        while pending and (~eng.active).any():
            eng.add_request(pending.pop(), max_new=max_new)
        tokens_out += len(eng.step())
    dt = time.monotonic() - t0
    print(f"{n_requests} requests, {tokens_out} decode ticks in {dt:.2f}s "
          f"({tokens_out / max(dt, 1e-9):.1f} batched-tok/s)")
    for i, out in enumerate(eng.outputs[:2]):
        print(f"  slot {i} sample: {out[:10]}...")


if __name__ == "__main__":
    demo("mamba2-780m")
    demo("h2o-danube-3-4b")
