"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--tier small|large|all] [--smoke]``

Every section that returns rows is also persisted as machine-readable
``BENCH_<name>.json`` at the repo root (see
:func:`benchmarks.common.write_bench_json`), so the perf trajectory is
collected across PRs — CI's smoke lane runs ``--smoke`` and uploads the
JSON files as artifacts.

``--smoke`` runs the fast, always-on subset (VSR accounting + the
batched-solver throughput/VM-overhead section with a reduced bag): a
quick signal that the numbers still materialize, not a rigorous timing.
The smoke lane doubles as the stream-VM dispatch regression guard: after
the JSON is written it exits nonzero if the specialized VM path's
``vm_overhead`` exceeds ``benchmarks.batched_solver.VM_OVERHEAD_MAX``
(1.25) — the ISSUE-6 gap (generic dispatch at 1.18×) must not creep
back into the production path.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="small",
                    choices=["small", "large", "all"])
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset for CI; still emits BENCH_*.json")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    from benchmarks import (batched_solver, fig9_residual_traces,
                            roofline_table, spmv_kernel, tab4_solver_time,
                            tab5_throughput, tab7_iterations,
                            vsr_access_counts)
    from benchmarks.common import write_bench_json

    sections = [
        ("vsr_access_counts",
         "§5.5 VSR access accounting (naive 19 -> 14 -> 13)",
         vsr_access_counts.run, {}),
        ("tab4_solver_time", "Table 4: solver time", tab4_solver_time.run,
         {"tier": args.tier}),
        ("tab5_throughput", "Table 5: throughput + fraction-of-peak",
         tab5_throughput.run, {"tier": args.tier}),
        ("tab7_iterations", "Table 7: iteration counts vs FP64",
         tab7_iterations.run, {"tier": args.tier}),
        ("fig9_residual_traces", "Fig. 9: residual traces",
         fig9_residual_traces.run, {}),
        ("spmv_kernel", "Kernel: SpMV stream bytes per scheme",
         spmv_kernel.run, {"tier": args.tier}),
        ("roofline_table", "Roofline: dry-run table (single pod)",
         roofline_table.run, {}),
        ("batched_solver",
         "Batched solver: systems/sec + stream-VM overhead",
         batched_solver.run, {"smoke": args.smoke}),
    ]
    if args.smoke:
        keep = {"vsr_access_counts", "batched_solver"}
        sections = [s for s in sections if s[0] in keep]

    failures = []
    for name, title, fn, kw in sections:
        print(f"\n=== {title} ===")
        t0 = time.time()
        rows = fn(**kw)
        elapsed = time.time() - t0
        if rows is not None:
            write_bench_json(name, rows,
                             meta={"tier": args.tier, "smoke": args.smoke,
                                   "elapsed_s": round(elapsed, 2)})
        print(f"--- ({elapsed:.1f}s)")
        if name == "batched_solver" and args.smoke:
            # Regression guard (after the JSON is persisted, so a failing
            # run still uploads its numbers as a CI artifact).
            try:
                batched_solver.check_vm_overhead(rows)
            except SystemExit as e:
                failures.append(str(e))

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
