"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--tier small|large|all]``
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="small",
                    choices=["small", "large", "all"])
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    from benchmarks import (batched_solver, fig9_residual_traces,
                            roofline_table, spmv_kernel, tab4_solver_time,
                            tab5_throughput, tab7_iterations,
                            vsr_access_counts)

    sections = [
        ("§5.5 VSR access accounting (naive 19 -> 14 -> 13)",
         vsr_access_counts.run, {}),
        ("Table 4: solver time", tab4_solver_time.run,
         {"tier": args.tier}),
        ("Table 5: throughput + fraction-of-peak", tab5_throughput.run,
         {"tier": args.tier}),
        ("Table 7: iteration counts vs FP64", tab7_iterations.run,
         {"tier": args.tier}),
        ("Fig. 9: residual traces", fig9_residual_traces.run, {}),
        ("Kernel: SpMV stream bytes per scheme", spmv_kernel.run,
         {"tier": args.tier}),
        ("Roofline: dry-run table (single pod)", roofline_table.run, {}),
        ("Batched solver: systems/sec vs Python loop",
         batched_solver.run, {}),
    ]
    for title, fn, kw in sections:
        print(f"\n=== {title} ===")
        t0 = time.time()
        fn(**kw)
        print(f"--- ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
