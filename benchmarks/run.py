"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--tier small|large|all] [--smoke]
[--profile DIR]``

Every section that returns rows is also persisted as machine-readable
``BENCH_<name>.json`` at the repo root (see
:func:`benchmarks.common.write_bench_json`), so the perf trajectory is
collected across PRs — CI's smoke lane runs ``--smoke`` and uploads the
JSON files as artifacts.

``--smoke`` runs the fast, always-on subset (VSR accounting + the
batched-solver throughput/VM-overhead section with a reduced bag): a
quick signal that the numbers still materialize, not a rigorous timing.
The smoke lane doubles as three regression guards on the batched
solver: after the JSON is written it exits nonzero if ``vm_overhead``
exceeds ``benchmarks.batched_solver.VM_OVERHEAD_MAX`` (1.25, the
ISSUE-6 dispatch gap), if ``speedup`` over ``python_loop`` drops below
``benchmarks.batched_solver.SPEC_SPEEDUP_MIN`` (1.5, the ISSUE-7
batched-loop gap), or if sliced-ELL's throughput on the skewed
power-law bag falls below ``SELL_SPEEDUP_MIN`` of row-ELL's (the
ISSUE-8 layout guard — all floors are recorded in the section's JSON
``meta``).  The ``engine_health`` section adds two more (ISSUE 9): a
deliberately-singular lane must exit ``BREAKDOWN_INDEFINITE`` in fewer
than maxiter iterations, and the engine's ``bytes_streamed_est`` metric
must agree with the packed-array accounting within
``benchmarks.engine_health.BYTES_REL_ERR_MAX`` (1%).

``--profile DIR`` wraps every section in a ``jax.profiler`` trace
(``benchmarks.common.profile_trace``) written under ``DIR/<section>``
for TensorBoard/Perfetto; profiling is strictly opt-in because it
costs time and disk.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="small",
                    choices=["small", "large", "all"])
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset for CI; still emits BENCH_*.json")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="write a jax.profiler trace per section under "
                         "DIR/<section> (TensorBoard/Perfetto); off by "
                         "default")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    from benchmarks import (batched_solver, engine_health,
                            fig9_residual_traces, roofline_table,
                            spmv_kernel, tab4_solver_time, tab5_throughput,
                            tab7_iterations, vsr_access_counts)
    from benchmarks.common import profile_trace, write_bench_json

    sections = [
        ("vsr_access_counts",
         "§5.5 VSR access accounting (naive 19 -> 14 -> 13)",
         vsr_access_counts.run, {}),
        ("tab4_solver_time", "Table 4: solver time", tab4_solver_time.run,
         {"tier": args.tier}),
        ("tab5_throughput", "Table 5: throughput + fraction-of-peak",
         tab5_throughput.run, {"tier": args.tier}),
        ("tab7_iterations", "Table 7: iteration counts vs FP64",
         tab7_iterations.run, {"tier": args.tier}),
        ("fig9_residual_traces", "Fig. 9: residual traces",
         fig9_residual_traces.run, {}),
        ("spmv_kernel", "Kernel: SpMV stream bytes per scheme",
         spmv_kernel.run, {"tier": args.tier}),
        ("roofline_table", "Roofline: dry-run table (single pod)",
         roofline_table.run, {}),
        ("batched_solver",
         "Batched solver: systems/sec + stream-VM overhead",
         batched_solver.run, {"smoke": args.smoke}),
        ("engine_health",
         "Engine health: breakdown lifecycle + metrics accounting",
         engine_health.run, {"smoke": args.smoke}),
    ]
    if args.smoke:
        keep = {"vsr_access_counts", "batched_solver", "engine_health"}
        sections = [s for s in sections if s[0] in keep]

    failures = []
    for name, title, fn, kw in sections:
        print(f"\n=== {title} ===")
        t0 = time.time()
        with profile_trace(f"{args.profile}/{name}" if args.profile
                           else None):
            rows = fn(**kw)
        elapsed = time.time() - t0
        if rows is not None:
            meta = {"tier": args.tier, "smoke": args.smoke,
                    "elapsed_s": round(elapsed, 2)}
            if name == "batched_solver":
                meta["vm_overhead_max"] = batched_solver.VM_OVERHEAD_MAX
                meta["spec_speedup_min"] = batched_solver.SPEC_SPEEDUP_MIN
                meta["sell_speedup_min"] = batched_solver.SELL_SPEEDUP_MIN
                meta["sell_bytes_reduction_min"] = (
                    batched_solver.SELL_BYTES_REDUCTION_MIN)
                meta["steps_per_sync"] = batched_solver.STEPS_PER_SYNC
            if name == "engine_health":
                meta["bytes_rel_err_max"] = engine_health.BYTES_REL_ERR_MAX
            write_bench_json(name, rows, meta=meta)
        print(f"--- ({elapsed:.1f}s)")
        if args.smoke:
            # Regression guards (after the JSON is persisted, so a
            # failing run still uploads its numbers as a CI artifact).
            guards = {
                "batched_solver": (batched_solver.check_vm_overhead,
                                   batched_solver.check_spec_speedup,
                                   batched_solver.check_sell_speedup),
                "engine_health": (engine_health.check_breakdown,
                                  engine_health.check_bytes),
            }.get(name, ())
            for guard in guards:
                try:
                    guard(rows)
                except SystemExit as e:
                    failures.append(str(e))

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
