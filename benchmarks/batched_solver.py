"""Batched multi-system solver throughput + stream-VM dispatch overhead.

Four ways to solve the same bag of heterogeneous SPD systems:

* ``python_loop`` — one-by-one through ``jpcg_solve`` (one compiled loop
  per padded bucket, dispatched serially from Python);
* ``batched_phases`` — all systems in ONE compiled ``lax.while_loop``
  through the phase-fused engine (``engine="phases"``, the oracle);
* ``batched_vm`` — the same batch through the *generic* stream VM: the
  program is a traced operand dispatched word-at-a-time by
  ``lax.switch`` (``engine="vm", specialize=False``, the fallback path);
* ``batched_vm_spec`` — the *specialized* stream VM: the compiled
  paper-policy program unrolled into the executable at trace time
  (``engine="vm"``, the production default).

Reading the numbers: on a *serial CPU host* the loop generally wins —
every padded FLOP executes sequentially and the batch runs until its
slowest lane converges; the CPU batched/loop ratio is the padding +
convergence-sync overhead this benchmark tracks, and the throughput win
appears on SIMD hardware (TPU) where extra lanes occupy otherwise-idle
vector lanes.  ``vm_overhead`` (t_vm / t_phases) is the dispatch cost of
each VM path relative to the phase-fused loop for the *same arithmetic*
— both VM paths are bit-identical to phases, so any gap is pure
dispatch.  ``spec_speedup`` (t_generic_vm / t_spec_vm) is what
trace-time program specialization buys.  The production path's
``vm_overhead`` (the ``batched_vm_spec`` row) is the guarded headline:
``benchmarks/run.py --smoke`` exits nonzero when it exceeds
:data:`VM_OVERHEAD_MAX` (see :func:`check_vm_overhead`), so the
dispatch gap cannot silently regress in CI.

``python -m benchmarks.batched_solver [--repeat-suite N] [--smoke]
[--overhead-threshold X]``
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.batch import batch_cache_info, jpcg_solve_batched
from repro.core.cg import jpcg_solve
from repro.sparse import diag_dominant_spd, poisson_2d, tridiagonal_spd

HEADER = ["mode", "systems", "total_iters", "time_s", "systems_per_s",
          "speedup", "vm_overhead", "spec_speedup"]

BK = dict(block_rows=8, col_tile=128)

#: CI regression guard: the production (specialized) VM path may cost at
#: most this factor over the phase-fused oracle before the smoke lane
#: fails.  The steady-state target is ≤ 1.05; the guard leaves headroom
#: for noisy CI runners.
VM_OVERHEAD_MAX = 1.25


def _bag(copies: int = 1, smoke: bool = False):
    if smoke:
        return [poisson_2d(16), tridiagonal_spd(300),
                diag_dominant_spd(300, nnz_per_row=8, dominance=1.2,
                                  seed=1)]
    base = [
        poisson_2d(24),
        poisson_2d(30),
        tridiagonal_spd(700),
        tridiagonal_spd(900, off=-0.8),
        diag_dominant_spd(600, nnz_per_row=10, dominance=1.1, seed=1),
        diag_dominant_spd(800, nnz_per_row=12, dominance=1.15, seed=2),
        diag_dominant_spd(500, nnz_per_row=8, dominance=1.2, seed=3),
        poisson_2d(20),
    ]
    return base * copies


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    sync = out[-1].x if isinstance(out, list) else out.x
    jax.block_until_ready(sync)
    return out, time.perf_counter() - t0


def check_vm_overhead(rows, threshold: float = VM_OVERHEAD_MAX):
    """Raise ``SystemExit`` (nonzero) if the production VM path's
    dispatch overhead exceeds ``threshold`` — the CI regression guard."""
    spec = next(r for r in rows if r["mode"] == "batched_vm_spec")
    if spec["vm_overhead"] > threshold:
        raise SystemExit(
            f"stream-VM dispatch regression: specialized vm_overhead "
            f"{spec['vm_overhead']} > {threshold} (t_spec/t_phases); "
            "the program-specialized path must stay fused — see "
            "ARCHITECTURE.md §specialization")


def run(repeat_suite: int = 1, smoke: bool = False):
    jax.config.update("jax_enable_x64", True)
    probs = _bag(repeat_suite, smoke=smoke)
    kw = dict(tol=1e-12, maxiter=1000 if smoke else 4000)

    # warm-up all four paths (compile), then time
    for a in probs:
        jpcg_solve(a, **kw, **BK)
    jpcg_solve_batched(probs, **kw, engine="phases", **BK)
    jpcg_solve_batched(probs, **kw, engine="vm", specialize=False, **BK)
    jpcg_solve_batched(probs, **kw, engine="vm", **BK)

    singles, t_loop = _timed(
        lambda: [jpcg_solve(a, **kw, **BK) for a in probs])
    phases, t_phases = _timed(
        jpcg_solve_batched, probs, **kw, engine="phases", **BK)
    vm, t_vm = _timed(jpcg_solve_batched, probs, **kw, engine="vm",
                      specialize=False, **BK)
    spec, t_spec = _timed(jpcg_solve_batched, probs, **kw, engine="vm",
                          **BK)

    for s, p, v, sp in zip(singles, phases, vm, spec):
        assert abs(s.iterations - p.iterations) <= 1, "parity violated"
        for r, label in ((v, "generic VM"), (sp, "specialized VM")):
            assert r.iterations == p.iterations, f"{label}/phases parity"
            assert np.array_equal(np.asarray(r.x), np.asarray(p.x)), \
                f"{label} not bit-identical to phases engine"

    def row(mode, res, t, vm_overhead="", spec_speedup=""):
        return {"mode": mode, "systems": len(probs),
                "total_iters": sum(r.iterations for r in res),
                "time_s": round(t, 4),
                "systems_per_s": round(len(probs) / t, 2),
                "speedup": round(t_loop / t, 2),
                "vm_overhead": vm_overhead,
                "spec_speedup": spec_speedup}

    rows = [
        row("python_loop", singles, t_loop),
        row("batched_phases", phases, t_phases),
        row("batched_vm", vm, t_vm,
            vm_overhead=round(t_vm / t_phases, 2)),
        row("batched_vm_spec", spec, t_spec,
            vm_overhead=round(t_spec / t_phases, 2),
            spec_speedup=round(t_vm / t_spec, 2)),
    ]
    emit(rows, HEADER)
    print(f"# batch compile cache: {batch_cache_info()}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat-suite", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--overhead-threshold", type=float, default=None,
                    help="fail (exit nonzero) if the specialized path's "
                         "vm_overhead exceeds this (CI uses "
                         f"{VM_OVERHEAD_MAX})")
    args = ap.parse_args()
    out = run(repeat_suite=args.repeat_suite, smoke=args.smoke)
    if args.overhead_threshold is not None:
        check_vm_overhead(out, args.overhead_threshold)
