"""Batched multi-system solver throughput + stream-VM dispatch overhead.

Four ways to solve the same bag of heterogeneous SPD systems:

* ``python_loop`` — one-by-one through ``jpcg_solve`` (one compiled loop
  per padded bucket, dispatched serially from Python);
* ``batched_phases`` — all systems in ONE compiled ``lax.while_loop``
  through the phase-fused engine (``engine="phases"``, the oracle);
* ``batched_vm`` — the same batch through the *generic* stream VM: the
  program is a traced operand dispatched word-at-a-time by
  ``lax.switch`` (``engine="vm", specialize=False``, the fallback path);
* ``batched_vm_spec`` — the *specialized* stream VM: the compiled
  paper-policy program unrolled into the executable at trace time
  (``engine="vm"``, the production default).

Reading the numbers: on a *serial CPU host* the loop generally wins —
every padded FLOP executes sequentially and the batch runs until its
slowest lane converges; the CPU batched/loop ratio is the padding +
convergence-sync overhead this benchmark tracks, and the throughput win
appears on SIMD hardware (TPU) where extra lanes occupy otherwise-idle
vector lanes.  ``vm_overhead`` (t_vm / t_phases) is the dispatch cost of
each VM path relative to the phase-fused loop for the *same arithmetic*
— both VM paths are bit-identical to phases, so any gap is pure
dispatch.  ``spec_speedup`` (t_generic_vm / t_spec_vm) is what
trace-time program specialization buys.  The production path's
``vm_overhead`` (the ``batched_vm_spec`` row) is the guarded headline:
``benchmarks/run.py --smoke`` exits nonzero when it exceeds
:data:`VM_OVERHEAD_MAX` (see :func:`check_vm_overhead`), so the
dispatch gap cannot silently regress in CI.

Each batched row also reports ``iters_per_s`` — total CG iterations
retired per second across the whole bag — ``chunk``, the
``steps_per_sync`` iteration-chunking knob the run used (ISSUE 7: k
iterations per termination sync, bit-identical for any k) — and the
layout economics (ISSUE 8): ``layout`` is the stacked layout the run
packed (``choose_layout``'s pick for the default ``layout="auto"``
rows, the explicit override for the skew rows), ``padding_ratio`` is
stored slots / nnz for that packing, and ``stream_bytes_per_nnz`` the
measured matrix-stream bytes (at-rest values + local indices, padding
included) per useful nonzero.

The ``skew_vm_rowell`` / ``skew_vm_sell`` rows time the SAME skewed
power-law bag through the specialized VM with the layout forced each
way — sliced-ELL exists for exactly this bag shape, so the smoke lane
guards that it doesn't lose throughput (:func:`check_sell_speedup`,
floor :data:`SELL_SPEEDUP_MIN`) and ``run`` asserts the headline byte
claim: mixed-V3 sliced-ELL streams ≥ :data:`SELL_BYTES_REDUCTION_MIN`
fewer bytes/nnz than FP64-at-rest row-ELL, measured from the packed
arrays.  Both layouts are bit-identical (asserted below).

The ``sharded_vm_d1`` / ``sharded_vm_d8`` rows (ISSUE 10) time the
default bag through the specialized VM with the lane axis placed on a
``lane_mesh()`` — each in a child interpreter that forces the host
device count (1 vs 8 CPU devices via ``XLA_FLAGS``), because the
parent session must keep a single device.  Lane sharding is
bit-identical by contract (asserted in-process below and property-
tested in tests/test_shard.py), so the row pair is pure throughput:
on a serial CPU host the 8-way split is bookkeeping overhead; the
ratio is the number to watch on hardware with real parallel devices.

``python -m benchmarks.batched_solver [--repeat-suite N] [--smoke]
[--overhead-threshold X] [--speedup-floor X] [--sell-floor X]``
"""
from __future__ import annotations

import json
import os
import statistics
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.batch import batch_cache_info, jpcg_solve_batched
from repro.core.cg import jpcg_solve
from repro.core.precision import get_scheme
from repro.core.shard import lane_mesh
from repro.sparse import (diag_dominant_spd, poisson_2d, powerlaw_spd,
                          tridiagonal_spd)
from repro.sparse.stacking import choose_layout, stack_rowell, stack_sell

HEADER = ["mode", "systems", "total_iters", "time_s", "systems_per_s",
          "iters_per_s", "chunk", "layout", "padding_ratio",
          "stream_bytes_per_nnz", "speedup", "vm_overhead",
          "spec_speedup"]

BK = dict(block_rows=8, col_tile=128)

#: Iteration-chunking knob under test — joins every batched row.
STEPS_PER_SYNC = 8

#: CI regression guard: the production (specialized) VM path may cost at
#: most this factor over the phase-fused oracle before the smoke lane
#: fails.  The steady-state target is ≤ 1.05; the guard leaves headroom
#: for noisy CI runners.
VM_OVERHEAD_MAX = 1.25

#: CI regression guard (ISSUE 7): the specialized VM path must beat the
#: python_loop baseline by at least this factor.  Steady state after the
#: row-ELL + chunking rework is ~4–6× on the smoke bag; the floor is set
#: well below that so only a structural regression (e.g. the scatter
#: SpMV creeping back, which ran at ~0.03×) trips it, not CI noise.
SPEC_SPEEDUP_MIN = 1.5

#: CI regression guard (ISSUE 8): on the skewed power-law bag —
#: sliced-ELL's home turf — the sell-packed specialized VM must be no
#: slower than the row-ELL packing (systems/s ratio ≥ this floor).
#: Steady state is ≥ 1 because sell runs strictly fewer padded slots;
#: the floor sits slightly below parity to absorb CI timer noise on a
#: bag where both paths take single-digit ms.
SELL_SPEEDUP_MIN = 0.95

#: Headline byte claim asserted by :func:`run` (ISSUE 8 acceptance):
#: mixed-V3 sliced-ELL must stream at least this fraction fewer
#: bytes/nnz than FP64-at-rest row-ELL on the skewed bag, measured
#: from the packed arrays (fp32+int16 at lower padding vs fp64+int16).
SELL_BYTES_REDUCTION_MIN = 0.40


#: Host device counts for the lane-sharded rows; each runs in a child
#: interpreter with XLA_FLAGS forcing the split (the parent session
#: stays single-device — same rule as tests/conftest.py).
SHARD_DEVICES = (1, 8)


def _sharded_row_times(devices: int, smoke: bool, steps_per_sync: int,
                       maxiter: int) -> dict:
    """Median sharded-solve wall time under N forced host devices,
    measured inside a child interpreter (timing excludes the child's
    startup and compile — warm-up happens before the clock starts)."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        import json
        import statistics
        import time
        import jax
        jax.config.update("jax_enable_x64", True)
        from benchmarks.batched_solver import BK, _bag
        from repro.core.batch import jpcg_solve_batched
        from repro.core.shard import lane_mesh
        probs = _bag(1, smoke={smoke})
        kw = dict(tol=1e-12, maxiter={maxiter},
                  steps_per_sync={steps_per_sync}, mesh=lane_mesh(),
                  engine="vm", **BK)
        res = jpcg_solve_batched(probs, **kw)          # compile
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            res = jpcg_solve_batched(probs, **kw)
            jax.block_until_ready(res[-1].x)
            times.append(time.perf_counter() - t0)
        print(json.dumps({{
            "devices": jax.device_count(),
            "time_s": statistics.median(times),
            "iters": int(sum(r.iterations for r in res)),
            "systems": len(probs)}}))
        """)
    r = subprocess.run([sys.executable, "-c", script],
                       env=os.environ.copy(), capture_output=True,
                       text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError("sharded bench subprocess (devices="
                           f"{devices}) failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _bag(copies: int = 1, smoke: bool = False):
    if smoke:
        return [poisson_2d(16), tridiagonal_spd(300),
                diag_dominant_spd(300, nnz_per_row=8, dominance=1.2,
                                  seed=1)]
    base = [
        poisson_2d(24),
        poisson_2d(30),
        tridiagonal_spd(700),
        tridiagonal_spd(900, off=-0.8),
        diag_dominant_spd(600, nnz_per_row=10, dominance=1.1, seed=1),
        diag_dominant_spd(800, nnz_per_row=12, dominance=1.15, seed=2),
        diag_dominant_spd(500, nnz_per_row=8, dominance=1.2, seed=3),
        poisson_2d(20),
    ]
    return base * copies


def _skew_bag(smoke: bool = False):
    """Power-law row-degree bag — the padding-heavy shape sliced-ELL
    targets (row-ELL pads every row to the global max width)."""
    if smoke:
        return [powerlaw_spd(512, alpha=2.1, seed=5),
                powerlaw_spd(300, alpha=2.2, seed=1),
                powerlaw_spd(400, alpha=2.0, seed=2)]
    return [powerlaw_spd(2048, alpha=2.1, seed=5),
            powerlaw_spd(1500, alpha=2.2, seed=1),
            powerlaw_spd(1024, alpha=2.0, seed=2),
            powerlaw_spd(900, alpha=2.3, seed=3)]


def _timed(fn, *args, repeats: int = 7, **kw):
    """Median wall time over ``repeats`` runs (post-warm-up the paths
    here take single-digit ms, where one-shot timing is all noise)."""
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        sync = out[-1].x if isinstance(out, list) else out.x
        jax.block_until_ready(sync)
        times.append(time.perf_counter() - t0)
    return out, statistics.median(times)


def check_vm_overhead(rows, threshold: float = VM_OVERHEAD_MAX):
    """Raise ``SystemExit`` (nonzero) if the production VM path's
    dispatch overhead exceeds ``threshold`` — the CI regression guard."""
    spec = next(r for r in rows if r["mode"] == "batched_vm_spec")
    if spec["vm_overhead"] > threshold:
        raise SystemExit(
            f"stream-VM dispatch regression: specialized vm_overhead "
            f"{spec['vm_overhead']} > {threshold} (t_spec/t_phases); "
            "the program-specialized path must stay fused — see "
            "ARCHITECTURE.md §specialization")


def check_spec_speedup(rows, floor: float = SPEC_SPEEDUP_MIN):
    """Raise ``SystemExit`` (nonzero) if the production VM path's
    speedup over the python_loop baseline drops below ``floor`` — the
    ISSUE-7 batched-loop-gap regression guard."""
    spec = next(r for r in rows if r["mode"] == "batched_vm_spec")
    if spec["speedup"] < floor:
        raise SystemExit(
            f"batched-loop regression: specialized VM speedup "
            f"{spec['speedup']}x over python_loop is below the floor "
            f"{floor}x; the batched hot loop must stay state-update "
            "bound — see ARCHITECTURE.md §iteration-economics")


def check_sell_speedup(rows, floor: float = SELL_SPEEDUP_MIN):
    """Raise ``SystemExit`` (nonzero) if sliced-ELL loses throughput to
    row-ELL on the skewed bag — the ISSUE-8 layout regression guard."""
    sell = next(r for r in rows if r["mode"] == "skew_vm_sell")
    rowell = next(r for r in rows if r["mode"] == "skew_vm_rowell")
    ratio = sell["systems_per_s"] / rowell["systems_per_s"]
    if ratio < floor:
        raise SystemExit(
            f"sliced-ELL regression: sell/rowell throughput ratio "
            f"{ratio:.2f} on the skewed bag is below the floor {floor} "
            "(sell runs strictly fewer padded slots there) — see "
            "ARCHITECTURE.md §sparse-layouts")


def run(repeat_suite: int = 1, smoke: bool = False,
        steps_per_sync: int = STEPS_PER_SYNC):
    jax.config.update("jax_enable_x64", True)
    probs = _bag(repeat_suite, smoke=smoke)
    kw = dict(tol=1e-12, maxiter=1000 if smoke else 4000)
    bkw = dict(steps_per_sync=steps_per_sync, **kw, **BK)

    # warm-up all four paths (compile), then time
    for a in probs:
        jpcg_solve(a, **kw, **BK)
    jpcg_solve_batched(probs, engine="phases", **bkw)
    jpcg_solve_batched(probs, engine="vm", specialize=False, **bkw)
    jpcg_solve_batched(probs, engine="vm", **bkw)

    singles, t_loop = _timed(
        lambda: [jpcg_solve(a, **kw, **BK) for a in probs])
    phases, t_phases = _timed(
        jpcg_solve_batched, probs, engine="phases", **bkw)
    vm, t_vm = _timed(jpcg_solve_batched, probs, engine="vm",
                      specialize=False, **bkw)
    spec, t_spec = _timed(jpcg_solve_batched, probs, engine="vm", **bkw)

    for s, p, v, sp in zip(singles, phases, vm, spec):
        # single-solver layout (banked ELL) sums in a different fp order
        # than the batched row-ELL, so iteration parity is near, not exact
        assert abs(s.iterations - p.iterations) <= 2, "parity violated"
        for r, label in ((v, "generic VM"), (sp, "specialized VM")):
            assert r.iterations == p.iterations, f"{label}/phases parity"
            assert np.array_equal(np.asarray(r.x), np.asarray(p.x)), \
                f"{label} not bit-identical to phases engine"

    def row(mode, res, t, bag, chunk="", layout="", stacked=None,
            speedup="", vm_overhead="", spec_speedup=""):
        iters = sum(r.iterations for r in res)
        return {"mode": mode, "systems": len(bag),
                "total_iters": iters,
                "time_s": round(t, 4),
                "systems_per_s": round(len(bag) / t, 2),
                "iters_per_s": round(iters / t, 1),
                "chunk": chunk,
                "layout": layout,
                "padding_ratio": (f"{stacked.padding_ratio:.3f}"
                                  if stacked is not None else ""),
                "stream_bytes_per_nnz": (
                    f"{stacked.stream_bytes_per_nnz():.2f}"
                    if stacked is not None else ""),
                "speedup": speedup,
                "vm_overhead": vm_overhead,
                "spec_speedup": spec_speedup}

    # the batched rows above all packed layout="auto"; measure what the
    # heuristic actually chose for this bag (at the default scheme)
    sch = get_scheme("mixed_v3")
    chosen = choose_layout(probs, default="rowell")
    stack = stack_sell if chosen == "sell" else stack_rowell
    st = stack(probs, scheme=sch)

    k = steps_per_sync
    rows = [
        row("python_loop", singles, t_loop, probs,
            speedup=round(t_loop / t_loop, 2)),
        row("batched_phases", phases, t_phases, probs, chunk=k,
            layout=chosen, stacked=st, speedup=round(t_loop / t_phases, 2)),
        row("batched_vm", vm, t_vm, probs, chunk=k,
            layout=chosen, stacked=st, speedup=round(t_loop / t_vm, 2),
            vm_overhead=round(t_vm / t_phases, 2)),
        row("batched_vm_spec", spec, t_spec, probs, chunk=k,
            layout=chosen, stacked=st, speedup=round(t_loop / t_spec, 2),
            vm_overhead=round(t_spec / t_phases, 2),
            spec_speedup=round(t_vm / t_spec, 2)),
    ]

    # --- ISSUE 8: skewed bag, row-ELL vs sliced-ELL head-to-head -----
    skew = _skew_bag(smoke=smoke)
    assert choose_layout(skew) == "sell", \
        "skew bag no longer trips the padding-ratio heuristic"
    skw = dict(steps_per_sync=steps_per_sync, **kw, **BK)
    jpcg_solve_batched(skew, engine="vm", layout="rowell", **skw)
    jpcg_solve_batched(skew, engine="vm", layout="sell", **skw)
    srow, t_srow = _timed(jpcg_solve_batched, skew, engine="vm",
                          layout="rowell", **skw)
    ssell, t_ssell = _timed(jpcg_solve_batched, skew, engine="vm",
                            layout="sell", **skw)
    for r, s in zip(srow, ssell):
        assert r.iterations == s.iterations, "sell/rowell parity"
        assert np.array_equal(np.asarray(r.x), np.asarray(s.x)), \
            "sliced-ELL not bit-identical to row-ELL"

    st_row = stack_rowell(skew, scheme=sch)
    st_sell = stack_sell(skew, scheme=sch)
    rows += [
        row("skew_vm_rowell", srow, t_srow, skew, chunk=k,
            layout="rowell", stacked=st_row),
        row("skew_vm_sell", ssell, t_ssell, skew, chunk=k,
            layout="sell", stacked=st_sell),
    ]

    # headline byte claim (ISSUE 8 acceptance): mixed-V3 at rest in
    # sliced-ELL vs FP64-at-rest row-ELL, measured from packed arrays
    st_fp64 = stack_rowell(skew, scheme=get_scheme("fp64"))
    reduction = 1 - (st_sell.stream_bytes_per_nnz()
                     / st_fp64.stream_bytes_per_nnz())
    print(f"# skew bag stream bytes/nnz: fp64 rowell "
          f"{st_fp64.stream_bytes_per_nnz():.2f} -> mixed_v3 sell "
          f"{st_sell.stream_bytes_per_nnz():.2f} "
          f"({reduction:.0%} reduction)")
    assert reduction >= SELL_BYTES_REDUCTION_MIN, (
        f"mixed_v3 sliced-ELL byte reduction {reduction:.0%} below the "
        f"{SELL_BYTES_REDUCTION_MIN:.0%} floor")

    # --- ISSUE 10: lane-sharded rows (forced host device counts) -----
    # contract check first: on this session's single device, placing the
    # lane axis on a mesh must be bitwise invisible vs the spec run
    shard = jpcg_solve_batched(probs, engine="vm", mesh=lane_mesh(), **bkw)
    for r, p in zip(shard, spec):
        assert r.iterations == p.iterations, "sharded/spec parity"
        assert np.array_equal(np.asarray(r.x), np.asarray(p.x)), \
            "lane-sharded run not bit-identical to unsharded VM"
    for d in SHARD_DEVICES:
        info = _sharded_row_times(d, smoke, steps_per_sync, kw["maxiter"])
        t = info["time_s"]
        rows.append({"mode": f"sharded_vm_d{info['devices']}",
                     "systems": info["systems"],
                     "total_iters": info["iters"],
                     "time_s": round(t, 4),
                     "systems_per_s": round(info["systems"] / t, 2),
                     "iters_per_s": round(info["iters"] / t, 1),
                     "chunk": k, "layout": chosen,
                     "padding_ratio": "", "stream_bytes_per_nnz": "",
                     "speedup": "", "vm_overhead": "",
                     "spec_speedup": ""})

    emit(rows, HEADER)
    print(f"# batch compile cache: {batch_cache_info()}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat-suite", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps-per-sync", type=int, default=STEPS_PER_SYNC,
                    help="iterations per termination sync (bit-identical "
                         "for any value; joins the 'chunk' column)")
    ap.add_argument("--overhead-threshold", type=float, default=None,
                    help="fail (exit nonzero) if the specialized path's "
                         "vm_overhead exceeds this (CI uses "
                         f"{VM_OVERHEAD_MAX})")
    ap.add_argument("--speedup-floor", type=float, default=None,
                    help="fail (exit nonzero) if the specialized path's "
                         "speedup over python_loop drops below this (CI "
                         f"uses {SPEC_SPEEDUP_MIN})")
    ap.add_argument("--sell-floor", type=float, default=None,
                    help="fail (exit nonzero) if sliced-ELL's systems/s "
                         "on the skewed bag falls below this fraction of "
                         f"row-ELL's (CI uses {SELL_SPEEDUP_MIN})")
    args = ap.parse_args()
    out = run(repeat_suite=args.repeat_suite, smoke=args.smoke,
              steps_per_sync=args.steps_per_sync)
    if args.overhead_threshold is not None:
        check_vm_overhead(out, args.overhead_threshold)
    if args.speedup_floor is not None:
        check_spec_speedup(out, args.speedup_floor)
    if args.sell_floor is not None:
        check_sell_speedup(out, args.sell_floor)
