"""Batched multi-system solver throughput — systems/sec vs a Python loop.

The serving claim of the batched engine, measured: solve the same bag of
heterogeneous SPD systems (a) one-by-one through ``jpcg_solve`` — one
compiled loop per padded bucket, dispatched serially from Python — and
(b) in one ``jpcg_solve_batched`` call — all systems in ONE compiled
``lax.while_loop`` with per-lane on-the-fly termination.

Reading the numbers: on a *serial CPU host* the loop generally wins —
every padded FLOP executes sequentially, each single solve is already
one compiled ``while_loop`` (no per-iteration dispatch to amortize), and
the batch runs until its slowest lane converges.  The CPU ratio is the
batched path's *overhead factor* (padding + convergence sync), which
this benchmark exists to track; the throughput win appears on SIMD
hardware (TPU) where the extra lanes occupy otherwise-idle vector lanes
and one executable serves the whole traffic stream.

``python -m benchmarks.batched_solver [--repeat-suite N]``
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.batch import batch_cache_info, jpcg_solve_batched
from repro.core.cg import jpcg_solve
from repro.sparse import diag_dominant_spd, poisson_2d, tridiagonal_spd

HEADER = ["mode", "systems", "total_iters", "time_s", "systems_per_s",
          "speedup"]

BK = dict(block_rows=8, col_tile=128)


def _bag(copies: int = 1):
    base = [
        poisson_2d(24),
        poisson_2d(30),
        tridiagonal_spd(700),
        tridiagonal_spd(900, off=-0.8),
        diag_dominant_spd(600, nnz_per_row=10, dominance=1.1, seed=1),
        diag_dominant_spd(800, nnz_per_row=12, dominance=1.15, seed=2),
        diag_dominant_spd(500, nnz_per_row=8, dominance=1.2, seed=3),
        poisson_2d(20),
    ]
    return base * copies


def run(repeat_suite: int = 1):
    jax.config.update("jax_enable_x64", True)
    probs = _bag(repeat_suite)
    kw = dict(tol=1e-12, maxiter=4000)

    # warm-up both paths (compile), then time
    for a in probs:
        jpcg_solve(a, **kw, **BK)
    jpcg_solve_batched(probs, **kw, **BK)

    t0 = time.perf_counter()
    singles = [jpcg_solve(a, **kw, **BK) for a in probs]
    jax.block_until_ready(singles[-1].x)
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    batched = jpcg_solve_batched(probs, **kw, **BK)
    jax.block_until_ready(batched[-1].x)
    t_batch = time.perf_counter() - t0

    for s, b in zip(singles, batched):
        assert abs(s.iterations - b.iterations) <= 1, "parity violated"

    rows = [
        {"mode": "python_loop", "systems": len(probs),
         "total_iters": sum(r.iterations for r in singles),
         "time_s": f"{t_loop:.4f}",
         "systems_per_s": f"{len(probs) / t_loop:.2f}", "speedup": "1.00"},
        {"mode": "batched", "systems": len(probs),
         "total_iters": sum(r.iterations for r in batched),
         "time_s": f"{t_batch:.4f}",
         "systems_per_s": f"{len(probs) / t_batch:.2f}",
         "speedup": f"{t_loop / t_batch:.2f}"},
    ]
    emit(rows, HEADER)
    print(f"# batch compile cache: {batch_cache_info()}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat-suite", type=int, default=1)
    run(**vars(ap.parse_args()))
