"""Batched multi-system solver throughput + stream-VM dispatch overhead.

Three ways to solve the same bag of heterogeneous SPD systems:

* ``python_loop`` — one-by-one through ``jpcg_solve`` (one compiled loop
  per padded bucket, dispatched serially from Python);
* ``batched_phases`` — all systems in ONE compiled ``lax.while_loop``
  through the phase-fused engine (``engine="phases"``, the oracle);
* ``batched_vm`` — the same batch through the stream VM executing the
  compiled paper-policy program (``engine="vm"``, the production path).

Reading the numbers: on a *serial CPU host* the loop generally wins —
every padded FLOP executes sequentially and the batch runs until its
slowest lane converges; the CPU batched/loop ratio is the padding +
convergence-sync overhead this benchmark tracks, and the throughput win
appears on SIMD hardware (TPU) where extra lanes occupy otherwise-idle
vector lanes.  ``vm_overhead`` (t_vm / t_phases) is the new number this
section collects: the cost of instruction-at-a-time ``lax.switch``
dispatch relative to the phase-fused loop for the *same arithmetic* —
the VM's results are bit-identical, so any gap is pure dispatch.

``python -m benchmarks.batched_solver [--repeat-suite N] [--smoke]``
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.batch import batch_cache_info, jpcg_solve_batched
from repro.core.cg import jpcg_solve
from repro.sparse import diag_dominant_spd, poisson_2d, tridiagonal_spd

HEADER = ["mode", "systems", "total_iters", "time_s", "systems_per_s",
          "speedup", "vm_overhead"]

BK = dict(block_rows=8, col_tile=128)


def _bag(copies: int = 1, smoke: bool = False):
    if smoke:
        return [poisson_2d(16), tridiagonal_spd(300),
                diag_dominant_spd(300, nnz_per_row=8, dominance=1.2,
                                  seed=1)]
    base = [
        poisson_2d(24),
        poisson_2d(30),
        tridiagonal_spd(700),
        tridiagonal_spd(900, off=-0.8),
        diag_dominant_spd(600, nnz_per_row=10, dominance=1.1, seed=1),
        diag_dominant_spd(800, nnz_per_row=12, dominance=1.15, seed=2),
        diag_dominant_spd(500, nnz_per_row=8, dominance=1.2, seed=3),
        poisson_2d(20),
    ]
    return base * copies


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    sync = out[-1].x if isinstance(out, list) else out.x
    jax.block_until_ready(sync)
    return out, time.perf_counter() - t0


def run(repeat_suite: int = 1, smoke: bool = False):
    jax.config.update("jax_enable_x64", True)
    probs = _bag(repeat_suite, smoke=smoke)
    kw = dict(tol=1e-12, maxiter=1000 if smoke else 4000)

    # warm-up all three paths (compile), then time
    for a in probs:
        jpcg_solve(a, **kw, **BK)
    jpcg_solve_batched(probs, **kw, engine="phases", **BK)
    jpcg_solve_batched(probs, **kw, engine="vm", **BK)

    singles, t_loop = _timed(
        lambda: [jpcg_solve(a, **kw, **BK) for a in probs])
    phases, t_phases = _timed(
        jpcg_solve_batched, probs, **kw, engine="phases", **BK)
    vm, t_vm = _timed(jpcg_solve_batched, probs, **kw, engine="vm", **BK)

    for s, p, v in zip(singles, phases, vm):
        assert abs(s.iterations - p.iterations) <= 1, "parity violated"
        assert v.iterations == p.iterations, "VM/phases parity violated"
        assert np.array_equal(np.asarray(v.x), np.asarray(p.x)), \
            "VM not bit-identical to phases engine"

    def row(mode, res, t, vm_overhead=""):
        return {"mode": mode, "systems": len(probs),
                "total_iters": sum(r.iterations for r in res),
                "time_s": round(t, 4),
                "systems_per_s": round(len(probs) / t, 2),
                "speedup": round(t_loop / t, 2),
                "vm_overhead": vm_overhead}

    rows = [
        row("python_loop", singles, t_loop),
        row("batched_phases", phases, t_phases),
        row("batched_vm", vm, t_vm,
            vm_overhead=round(t_vm / t_phases, 2)),
    ]
    emit(rows, HEADER)
    print(f"# batch compile cache: {batch_cache_info()}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat-suite", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    run(**vars(ap.parse_args()))
