"""Paper Fig. 9 — residual traces under the four precision settings.

Emits rr-per-iteration CSV (sampled) for an ill-conditioned problem where
the schemes separate: V1 floors above the threshold, V3 tracks FP64.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.cg import jpcg_solve
from repro.sparse import poisson_2d

HEADER = ["iter", "fp64", "mixed_v1", "mixed_v2", "mixed_v3"]


def run(n_side: int = 100, sample_every: int = 10):
    jax.config.update("jax_enable_x64", True)
    a = poisson_2d(n_side)
    traces = {}
    maxlen = 0
    for s in ("fp64", "mixed_v1", "mixed_v2", "mixed_v3"):
        r = jpcg_solve(a, scheme=s, tol=1e-12, maxiter=5000,
                       with_trace=True)
        traces[s] = np.asarray(r.residual_trace)
        maxlen = max(maxlen, traces[s].shape[0])
    rows = []
    for i in range(0, maxlen, sample_every):
        row = {"iter": i}
        for s, tr in traces.items():
            row[s] = f"{tr[min(i, tr.shape[0] - 1)]:.4e}"
        rows.append(row)
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
