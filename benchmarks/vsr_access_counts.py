"""Paper §5.5 — HBM vector-access accounting (the VSR claim).

naive 19 (14R+5W) → paper VSR 14 (10R+4W) → min-traffic 13 (9R+4W),
plus the derived Type-III memory-instruction counts and the per-iteration
HBM byte model for a reference large matrix.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.compile import compile_policy
from repro.core.isa import derived_mem_instructions
from repro.core.precision import get_scheme
from repro.core.vsr import access_counts, schedule
from repro.sparse.stacking import index_bytes_for

HEADER = ["schedule", "reads", "writes", "total", "isa_reads", "isa_writes",
          "bytes_per_iter_1M_v3"]


def run():
    counts = access_counts()
    rows = []
    n, nnz = 1_000_000, 5_000_000            # ecology2-class reference
    v3 = get_scheme("mixed_v3")
    for pol in ("naive", "paper", "min_traffic"):
        c = counts[pol]
        isa_r = isa_w = ""
        if pol in ("paper", "min_traffic"):
            m = derived_mem_instructions(compile_policy(pol).program)
            isa_r, isa_w = m["reads"], m["writes"]
            assert (m["reads"], m["writes"]) == (c["reads"], c["writes"]), \
                "compiled ISA program disagrees with VSR analysis"
        vec_bytes = c["total"] * n * v3.vector_bytes
        # real per-layout index width: int32 at n=1M (≥ 2^15 rows)
        mat_bytes = nnz * v3.nonzero_stream_bytes(
            index_bytes=index_bytes_for(n))
        rows.append({
            "schedule": pol, "reads": c["reads"], "writes": c["writes"],
            "total": c["total"], "isa_reads": isa_r, "isa_writes": isa_w,
            "bytes_per_iter_1M_v3": vec_bytes + mat_bytes,
        })
    s = schedule(policy="min_traffic")
    assert "z" in s.never_stored
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
