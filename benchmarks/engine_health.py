"""Engine health: breakdown lifecycle + metrics accounting (ISSUE 9).

Runs one :class:`repro.serve.SolverEngine` over a mixed bag — healthy
SPD lanes, a deliberately *singular* operand (the all-ones matrix
``J_n`` with a sum-zero rhs: ``ap = J·p = 0`` on the first search
direction, so ``pAp = 0``), and a NaN-seeded rhs — and reports each
request's structured exit next to the engine's observability snapshot.

Two properties double as smoke-lane regression guards
(``benchmarks/run.py --smoke``):

* :func:`check_breakdown` — the singular lane exits
  ``BREAKDOWN_INDEFINITE`` in **fewer than maxiter** iterations (before
  the health layer it spun the full budget and returned garbage wearing
  the MAXITER face);
* :func:`check_bytes` — ``metrics()["bytes_streamed_est"]`` agrees with
  an independent packed-array recompute — SpMV events (one warm-up per
  admit + one per committed iteration + one discarded tick per mid-loop
  breakdown) × the per-lane at-rest stream (values + indices, padding
  included) — within :data:`BYTES_REL_ERR_MAX` (1%).  The bag is all
  one size and the singular lane is admitted first, so the pool
  geometry never grows mid-run and the two accountings must coincide.

``python -m benchmarks.engine_health``
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit

HEADER = ["request", "n", "scheme", "status", "iterations", "converged",
          "retried", "bytes_streamed_est", "bytes_expected",
          "bytes_rel_err"]

#: Smoke guard: estimated vs packed-array-recomputed streamed bytes.
BYTES_REL_ERR_MAX = 0.01

_N = 32
_MAXITER_POISON = 200


def _singular():
    """J_n (rank 1, eigenvalues {n, 0, ..., 0}) + a sum-zero rhs: the
    warm-up is fine (diag is all ones), but the first search direction
    lies in the nullspace — ``pAp = 0`` on tick 1."""
    a = np.ones((_N, _N))
    b = np.zeros(_N)
    b[0], b[1] = 1.0, -1.0
    return a, b


def run(smoke: bool = False):
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.serve.solver_engine import SolverEngine, SolverEngineConfig
    from repro.sparse import tridiagonal_spd

    cfg = SolverEngineConfig(batch_slots=8, chunk_iters=16,
                             scheme="mixed_v3")
    eng = SolverEngine(cfg)

    names = {}
    a_sing, b_sing = _singular()
    # Singular lane first: it fixes the pool bucket at (n_pad, W) for
    # the whole run (every problem is n=32), keeping the byte
    # accounting exact — no mid-run geometry growth.
    names[eng.submit(a_sing, b_sing, tol=1e-12,
                     maxiter=_MAXITER_POISON)] = "singular_J32"
    for i in range(4):
        names[eng.submit(tridiagonal_spd(_N, diag=2.0 + 0.1 * i),
                         tol=1e-12, maxiter=2000)] = f"healthy_{i}"
    names[eng.submit(tridiagonal_spd(_N), np.full(_N, np.nan),
                     tol=1e-12, maxiter=_MAXITER_POISON)] = "nan_rhs"

    results = eng.run_to_completion()
    snap = eng.metrics()

    # Independent recompute from the packed arrays: every SpMV event
    # streams one lane's at-rest nonzero arrays (values + indices,
    # padding included).  Events: one warm-up per admit, one per
    # committed iteration, one discarded tick per breakdown that
    # happened *in-loop* — those freeze at their pre-tick (finite) rr,
    # while a lane latched non-finite at admission never ticked.
    pool = next(iter(eng._pools.values()))
    lane_bytes = pool._lane_stream_bytes()
    n_events = len(results)
    for r in results.values():
        n_events += r.iterations
        if r.status in ("BREAKDOWN_INDEFINITE",
                        "BREAKDOWN_NONFINITE") and np.isfinite(r.rr):
            n_events += 1
    expected = n_events * lane_bytes
    est = snap["bytes_streamed_est"]
    rel_err = abs(est - expected) / expected

    rows = []
    for rid, res in sorted(results.items()):
        rows.append({
            "request": names[rid], "n": _N, "scheme": res.scheme,
            "status": res.status, "iterations": res.iterations,
            "converged": res.converged, "retried": res.retried,
        })
    rows.append({
        "request": "ENGINE_TOTALS", "n": _N, "scheme": cfg.scheme,
        "status": "", "iterations": snap["iterations"], "converged": "",
        "retried": "", "bytes_streamed_est": est,
        "bytes_expected": expected, "bytes_rel_err": round(rel_err, 6),
    })
    emit(rows, HEADER)
    print(f"# engine metrics: {snap}")
    return rows


def _poison_row(rows, name):
    for r in rows:
        if r["request"] == name:
            return r
    raise SystemExit(f"engine_health: no '{name}' row emitted")


def check_breakdown(rows):
    """Smoke guard: the singular lane must exit ``BREAKDOWN_INDEFINITE``
    before its iteration budget — not spin to maxiter."""
    r = _poison_row(rows, "singular_J32")
    if r["status"] != "BREAKDOWN_INDEFINITE":
        raise SystemExit(
            f"engine_health: singular lane exited {r['status']!r}, "
            f"expected BREAKDOWN_INDEFINITE")
    if not r["iterations"] < _MAXITER_POISON:
        raise SystemExit(
            f"engine_health: singular lane burned its whole budget "
            f"({r['iterations']} >= maxiter={_MAXITER_POISON}) — "
            f"detection did not fire early")


def check_bytes(rows):
    """Smoke guard: metrics bytes-streamed vs packed-array accounting."""
    r = _poison_row(rows, "ENGINE_TOTALS")
    if r["bytes_rel_err"] > BYTES_REL_ERR_MAX:
        raise SystemExit(
            f"engine_health: bytes_streamed_est={r['bytes_streamed_est']} "
            f"disagrees with packed-array accounting "
            f"{r['bytes_expected']} by {r['bytes_rel_err']:.2%} "
            f"(max {BYTES_REL_ERR_MAX:.0%})")


if __name__ == "__main__":
    rows = run()
    check_breakdown(rows)
    check_bytes(rows)
