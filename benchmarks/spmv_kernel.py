"""Kernel-level microbenchmark — SpMV byte/FLOP accounting per scheme.

The paper's Challenge-3 arithmetic realized: per-nonzero stream bytes by
precision scheme, padding efficiency of the banked layouts, and the
bandwidth-bound time projection per SpMV on v5e.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.precision import SCHEMES
from repro.roofline.model import V5E
from repro.sparse import benchmark_suite, csr_to_bell
from repro.sparse.ellpack import csr_to_ellpack

HEADER = ["matrix", "nnz", "layout", "pad_eff", "scheme", "stream_MB",
          "proj_spmv_us_v5e"]


def run(tier: str = "small"):
    rows = []
    for name, a in list(benchmark_suite(tier).items())[:4]:
        bell = csr_to_bell(a, block_rows=256, col_tile=512)
        ell = csr_to_ellpack(a, block_rows=256, col_tile=512)
        for layout, m in (("bell", bell), ("ellpack", ell)):
            for scheme_name in ("fp64", "mixed_v3", "tpu_v3"):
                s = SCHEMES[scheme_name]
                nbytes = m.stored_entries * s.nonzero_stream_bytes()
                rows.append({
                    "matrix": name, "nnz": a.nnz, "layout": layout,
                    "pad_eff": f"{m.padding_efficiency:.3f}",
                    "scheme": scheme_name,
                    "stream_MB": f"{nbytes / 1e6:.2f}",
                    "proj_spmv_us_v5e": f"{nbytes / V5E.hbm_bw * 1e6:.1f}",
                })
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
