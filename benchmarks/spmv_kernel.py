"""Kernel-level microbenchmark — SpMV byte/FLOP accounting per scheme.

The paper's Challenge-3 arithmetic realized on the layouts the solver
actually runs: per-nonzero stream bytes by precision scheme, measured
(not modeled) from the packed arrays — values at the scheme's at-rest
``matrix_dtype``, one int16/int32 local column index per slot, padding
included.  ``padding_ratio`` (stored slots / nnz) is the bytes
multiplier a layout pays for rectangularity; sliced-ELL exists to pull
it toward 1 on skewed matrices, and the ``stream_bytes_per_nnz`` column
is where that shows up.  The bandwidth-bound v5e time projection uses
the measured byte count.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.precision import SCHEMES
from repro.roofline.model import V5E
from repro.sparse import benchmark_suite, csr_to_bell
from repro.sparse.ellpack import csr_to_ellpack
from repro.sparse.stacking import stack_rowell, stack_sell

HEADER = ["matrix", "nnz", "layout", "padding_ratio", "scheme",
          "stream_bytes_per_nnz", "stream_MB", "proj_spmv_us_v5e"]


def run(tier: str = "small"):
    rows = []
    for name, a in list(benchmark_suite(tier).items())[:4]:
        bell = csr_to_bell(a, block_rows=256, col_tile=512)
        ell = csr_to_ellpack(a, block_rows=256, col_tile=512)
        for scheme_name in ("fp64", "mixed_v3", "tpu_v3"):
            s = SCHEMES[scheme_name]
            per = {}
            # modeled: the banked/tiled kernels stream stored entries
            # at value + one local index each
            for layout, m in (("bell", bell), ("ellpack", ell)):
                stored = m.stored_entries
                nbytes = stored * s.nonzero_stream_bytes()
                per[layout] = (stored / max(a.nnz, 1), nbytes / a.nnz,
                               nbytes)
            # measured: the stacked batched layouts report their own
            # array sizes (at-rest dtype + real index width + padding)
            for layout, st in (("rowell", stack_rowell([a], scheme=s)),
                               ("sell", stack_sell([a], scheme=s))):
                per[layout] = (st.padding_ratio, st.stream_bytes_per_nnz(),
                               st.vals.nbytes + st.cols.nbytes)
            for layout, (ratio, bpnz, nbytes) in per.items():
                rows.append({
                    "matrix": name, "nnz": a.nnz, "layout": layout,
                    "padding_ratio": f"{ratio:.3f}",
                    "scheme": scheme_name,
                    "stream_bytes_per_nnz": f"{bpnz:.2f}",
                    "stream_MB": f"{nbytes / 1e6:.2f}",
                    "proj_spmv_us_v5e": f"{nbytes / V5E.hbm_bw * 1e6:.1f}",
                })
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
