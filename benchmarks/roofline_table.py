"""§Roofline — render the dry-run artifacts as the per-cell table."""
from __future__ import annotations

import os

from repro.roofline.report import format_table, load_results, one_liner

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run(mesh: str = "single"):
    res = load_results(os.path.join(ART, mesh))
    if not res:
        print(f"(no dry-run artifacts under experiments/dryrun/{mesh} — "
              f"run `python -m repro.launch.dryrun --all --mesh {mesh}`)")
        return []
    print(format_table(res))
    print()
    worst = sorted(res, key=lambda r: r.get("roofline", {}).get(
        "mfu_at_roofline") or 1.0)[:3]
    for r in worst:
        print(one_liner(r))
    return res


if __name__ == "__main__":
    run()
