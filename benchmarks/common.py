"""Shared benchmark utilities: suite, timing, profiling, CSV + JSON
output."""
from __future__ import annotations

import contextlib
import json
import pathlib
import platform
import time
from typing import Callable, Optional

import jax

#: repo root — BENCH_<name>.json files land here so the perf trajectory
#: is collected at a fixed, greppable location across PRs.
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def time_solve(fn: Callable, *args, repeats: int = 3, **kw):
    """Median wall time of fn(*args) with device sync."""
    best = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(getattr(out, "x", out))
        best.append(time.perf_counter() - t0)
    best.sort()
    return out, best[len(best) // 2]


@contextlib.contextmanager
def profile_trace(dirpath: Optional[str]):
    """Opt-in ``jax.profiler`` trace around a benchmark section.

    ``dirpath`` falsy → no-op (the default: profiling costs time and
    disk, so it never runs unless asked for).  Otherwise the section
    executes under ``jax.profiler.start_trace(dirpath)`` and the trace
    lands in ``dirpath`` for TensorBoard (``tensorboard --logdir``) or
    Perfetto (``ui.perfetto.dev``, load the ``*.trace.json.gz``).
    """
    if not dirpath:
        yield
        return
    jax.profiler.start_trace(str(dirpath))
    try:
        yield
    finally:
        jax.profiler.stop_trace()
        print(f"# profiler trace written under {dirpath}")


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    return rows


def write_bench_json(name: str, rows, meta: Optional[dict] = None) -> str:
    """Persist one benchmark section as ``BENCH_<name>.json`` (repo root).

    The payload is self-describing: rows as emitted, plus enough context
    (backend, host, timestamp) to compare runs across machines and PRs.
    Returns the path written.
    """
    payload = {
        "name": name,
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "host": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "default_backend": jax.default_backend(),
        },
        "meta": meta or {},
        "rows": rows,
    }
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    print(f"# wrote {path}")
    return str(path)
