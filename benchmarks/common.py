"""Shared benchmark utilities: suite, timing, CSV output."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_solve(fn: Callable, *args, repeats: int = 3, **kw):
    """Median wall time of fn(*args) with device sync."""
    best = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(getattr(out, "x", out))
        best.append(time.perf_counter() - t0)
    best.sort()
    return out, best[len(best) // 2]


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    return rows
