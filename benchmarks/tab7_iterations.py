"""Paper Table 7 — iteration counts per scheme vs the FP64 reference.

The paper's claim: CALLIPEPLA (Mix-V3) stays within a few iterations of
the CPU FP64 reference while XcgSolver drifts by hundreds–thousands.
Here the FP64 run is the reference; the diff column must be ≈0 for V3.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.cg import jpcg_solve
from repro.sparse import benchmark_suite

HEADER = ["matrix", "iters_fp64", "iters_v3", "diff_v3", "iters_v2",
          "diff_v2", "iters_v1", "diff_v1"]


def run(tier: str = "small"):
    jax.config.update("jax_enable_x64", True)
    rows = []
    for name, a in benchmark_suite(tier).items():
        its = {}
        for s in ("fp64", "mixed_v3", "mixed_v2", "mixed_v1"):
            r = jpcg_solve(a, scheme=s, tol=1e-12, maxiter=20_000)
            its[s] = r.iterations if r.converged else 20_000
        rows.append({
            "matrix": name,
            "iters_fp64": its["fp64"],
            "iters_v3": its["mixed_v3"],
            "diff_v3": its["mixed_v3"] - its["fp64"],
            "iters_v2": its["mixed_v2"],
            "diff_v2": its["mixed_v2"] - its["fp64"],
            "iters_v1": its["mixed_v1"],
            "diff_v1": its["mixed_v1"] - its["fp64"],
        })
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
