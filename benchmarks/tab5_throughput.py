"""Paper Table 5 — throughput (GFLOP/s) and fraction-of-peak.

FLOP counting follows the paper: per iteration the JPCG performs one SpMV
(2·nnz) + 3 dots (2n each) + 3 axpys (2n) + 1 element-wise divide (n) —
(# floating-point ops) / (solver time).  CPU-host numbers give the
measured column; the v5e projection divides the per-iteration byte
traffic (the solver is bandwidth-bound, §7.6) by 819 GB/s — exactly the
paper's f = BW/r matching argument, stated as a roofline.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_solve
from repro.core.cg import jpcg_solve
from repro.core.precision import get_scheme
from repro.core.vsr import schedule
from repro.roofline.model import V5E
from repro.sparse import benchmark_suite
from repro.sparse.stacking import index_bytes_for

HEADER = ["matrix", "n", "nnz", "scheme", "time_s", "iters", "gflops_host",
          "proj_v5e_gflops", "proj_fop_pct"]


def _flops_per_iter(n, nnz):
    return 2 * nnz + 3 * 2 * n + 3 * 2 * n + n


def _bytes_per_iter(n, nnz, scheme):
    """HBM bytes per iteration under the min-traffic VSR schedule."""
    s = schedule(policy="min_traffic")
    vec_bytes = (s.n_reads + s.n_writes) * n * scheme.vector_bytes
    # index width follows the layout actually packed for this n
    mat_bytes = nnz * scheme.nonzero_stream_bytes(
        index_bytes=index_bytes_for(n))
    return vec_bytes + mat_bytes


def run(tier: str = "small"):
    jax.config.update("jax_enable_x64", True)
    rows = []
    for name, a in benchmark_suite(tier).items():
        n, nnz = a.shape[0], a.nnz
        for scheme_name in ("fp64", "mixed_v3"):
            sch = get_scheme(scheme_name)
            res, t = time_solve(jpcg_solve, a, scheme=scheme_name,
                                tol=1e-12, maxiter=20_000)
            fl = _flops_per_iter(n, nnz) * res.iterations
            gf_host = fl / t / 1e9
            # bandwidth-bound projection on v5e
            bpi = _bytes_per_iter(n, nnz, sch)
            t_proj = bpi * res.iterations / V5E.hbm_bw
            gf_proj = fl / t_proj / 1e9
            fop = gf_proj * 1e9 / V5E.peak_flops("f32") * 100
            rows.append({
                "matrix": name, "n": n, "nnz": nnz, "scheme": scheme_name,
                "time_s": f"{t:.4f}", "iters": res.iterations,
                "gflops_host": f"{gf_host:.2f}",
                "proj_v5e_gflops": f"{gf_proj:.1f}",
                "proj_fop_pct": f"{fop:.3f}",
            })
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
