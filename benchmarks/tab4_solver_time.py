"""Paper Table 4 — solver time per matrix, XcgSolver-baseline protocol.

Four solver variants stand in for the paper's four platforms:

  ==============  =====================================================
  paper column    this repo
  ==============  =====================================================
  XcgSolver       fp64, naive (no VSR: method=vsr + fp64, no fusion win
                  is the closest honest CPU proxy)
  SerpensCG       fp64 + stream ISA (vm path, paper policy)
  CALLIPEPLA      mixed_v3 + VSR (the full reproduction)
  (beyond-paper)  mixed_v3 + pipelined single-reduction CG
  ==============  =====================================================

Protocol (§7.1): b = 1⃗, x₀ = 0⃗, stop at ‖r‖² < 1e-12, 20k iteration cap.
Wall times are CPU-host numbers (relative speedups are the signal; TPU
projections live in the roofline analysis).
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_solve
from repro.core.cg import jpcg_solve
from repro.sparse import benchmark_suite

HEADER = ["matrix", "n", "nnz", "fp64_s", "v3_vsr_s", "v3_pipe_s",
          "speedup_v3", "iters_fp64", "iters_v3"]


def run(tier: str = "small"):
    jax.config.update("jax_enable_x64", True)
    rows = []
    for name, a in benchmark_suite(tier).items():
        r64, t64 = time_solve(jpcg_solve, a, scheme="fp64", tol=1e-12,
                              maxiter=20_000)
        rv3, tv3 = time_solve(jpcg_solve, a, scheme="mixed_v3", tol=1e-12,
                              maxiter=20_000)
        rp, tp = time_solve(jpcg_solve, a, scheme="mixed_v3", tol=1e-12,
                            maxiter=20_000, method="pipelined")
        rows.append({
            "matrix": name, "n": a.shape[0], "nnz": a.nnz,
            "fp64_s": f"{t64:.4f}", "v3_vsr_s": f"{tv3:.4f}",
            "v3_pipe_s": f"{tp:.4f}",
            "speedup_v3": f"{t64 / tv3:.3f}",
            "iters_fp64": r64.iterations, "iters_v3": rv3.iterations,
        })
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
