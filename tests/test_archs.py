"""Per-architecture smoke tests (assignment requirement).

Each of the ten assigned architectures instantiates its REDUCED
same-family config and runs one forward + one train step + one decode
step on CPU, asserting output shapes and no NaNs.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, applicable, get_config, input_specs
from repro.models import (count_params, decode_step, forward_logits,
                          init_cache, init_params, loss_fn)
from repro.train import AdamWConfig, adamw_init, adamw_update

B, S = 2, 64


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    batch = {"tokens": toks, "labels": labels}
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                          jnp.float32)
    if cfg.encoder is not None:
        batch["audio_embeds"] = jnp.zeros((B, cfg.encoder.n_ctx,
                                           cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        assert count_params(params) > 0
        batch = _batch(cfg, key)
        logits = forward_logits(params, cfg, batch)
        assert logits.shape == (B, S, cfg.vocab)
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(params)
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        gleaves = jax.tree_util.tree_leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in gleaves), (
            f"{arch}: non-finite grads")
        opt = AdamWConfig(lr=1e-3, state_dtype="float32")
        new_params, _ = adamw_update(grads, adamw_init(params, opt), params,
                                     opt, jnp.asarray(1e-3))
        # params must actually move
        moved = any(
            bool(jnp.any(a != b)) for a, b in zip(
                jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(new_params)))
        assert moved

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        cache = init_cache(cfg, B, 32, dtype=jnp.float32)
        if cfg.encoder is not None:
            from repro.models import encdec
            enc = encdec.encode(params, cfg,
                                jnp.zeros((B, cfg.encoder.n_ctx,
                                           cfg.d_model)))
            ck, cv = encdec.prefill_cross(params, cfg, enc)
            cache["cross_k"], cache["cross_v"] = ck, cv
        tok = jax.random.randint(key, (B,), 0, cfg.vocab)
        logits, new_cache = decode_step(params, cfg, cache, tok,
                                        jnp.asarray(0))
        assert logits.shape == (B, cfg.vocab)
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN decode"
        # cache must change somewhere
        changed = any(
            bool(jnp.any(a != b)) for a, b in zip(
                jax.tree_util.tree_leaves(cache),
                jax.tree_util.tree_leaves(new_cache)))
        assert changed


class TestAssignmentMatrix:
    def test_exact_configs(self):
        """Published dims are exact (spot checks against the assignment)."""
        c = get_config("qwen2.5-32b")
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (64, 5120, 40, 8, 27_648, 152_064)
        assert c.qkv_bias
        c = get_config("granite-34b")
        assert (c.n_layers, c.d_model, c.n_kv_heads) == (88, 6144, 1)
        c = get_config("mamba2-780m")
        assert c.ssm.d_state == 128 and c.family == "ssm"
        c = get_config("zamba2-1.2b")
        assert c.ssm.d_state == 64 and c.family == "hybrid"
        c = get_config("gemma3-1b")
        assert c.local_global_ratio == 5 and c.vocab == 262_144
        c = get_config("granite-moe-1b-a400m")
        assert c.moe.n_experts == 32 and c.moe.top_k == 8
        c = get_config("llama4-scout-17b-a16e")
        assert c.moe.n_experts == 16 and c.moe.top_k == 1
        c = get_config("internvl2-76b")
        assert (c.n_layers, c.d_model, c.vocab) == (80, 8192, 128_256)
        c = get_config("whisper-base")
        assert c.encoder.n_layers == 6 and c.vocab == 51_865
        c = get_config("h2o-danube-3-4b")
        assert c.sliding_window is not None

    def test_40_cells_defined(self):
        """Every (arch × shape) cell is either runnable or a documented
        skip; 40 total, skips only on long_500k for full-attention archs."""
        total = runs = 0
        for a in ARCHS:
            cfg = get_config(a)
            for s, shape in SHAPES.items():
                total += 1
                ok, why = applicable(cfg, shape)
                if ok:
                    runs += 1
                else:
                    assert s == "long_500k" and why
        assert total == 40 and runs == 34

    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_input_specs_no_allocation(self, arch):
        """input_specs returns ShapeDtypeStructs (never real buffers)."""
        cfg = get_config(arch)
        for s, shape in SHAPES.items():
            if not applicable(cfg, shape)[0]:
                continue
            specs = input_specs(arch, s)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)

    def test_long_500k_runs_subquadratic_archs(self):
        for a in ("mamba2-780m", "zamba2-1.2b", "h2o-danube-3-4b",
                  "gemma3-1b"):
            assert applicable(get_config(a), SHAPES["long_500k"])[0], a
