"""Model substrate invariants: masks, RoPE, GQA, MoE, SSD, chunked attn."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import repro.models.attention as A
from repro.models.attention import (attention, attn_decode, init_attention,
                                    init_attn_cache)
from repro.models.config import MoEConfig, SSMConfig
from repro.models.layers import (apply_rope, init_mlp, init_rmsnorm, mlp,
                                 rmsnorm, rope_freqs)
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import (init_mamba2, init_ssm_cache, mamba2_decode,
                              mamba2_forward)

KEY = jax.random.PRNGKey(0)
F32 = jnp.float32


class TestRoPE:
    @given(shift=st.integers(1, 100))
    @settings(deadline=None, max_examples=10)
    def test_relative_position_invariance(self, shift):
        """⟨rope(q,i), rope(k,j)⟩ depends only on i−j."""
        q = jax.random.normal(KEY, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))

        def score(i, j):
            ci, si = rope_freqs(jnp.array([[i]]), 32)
            cj, sj = rope_freqs(jnp.array([[j]]), 32)
            return float(jnp.sum(apply_rope(q, ci, si)
                                 * apply_rope(k, cj, sj)))

        assert score(3, 5) == pytest.approx(score(3 + shift, 5 + shift),
                                            rel=1e-4, abs=1e-5)

    def test_norm_preserved(self):
        x = jax.random.normal(KEY, (2, 8, 4, 64))
        cos, sin = rope_freqs(jnp.arange(8)[None], 64)
        y = apply_rope(x, cos, sin)
        np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                                   jnp.linalg.norm(x, axis=-1), rtol=1e-5)


class TestAttentionMasks:
    def _p(self, kv=2):
        return init_attention(KEY, 32, 4, kv, 8)

    def test_causality(self):
        """Future tokens cannot influence past outputs."""
        p = self._p()
        x1 = jax.random.normal(KEY, (1, 16, 32))
        x2 = x1.at[:, 10:].set(jax.random.normal(jax.random.PRNGKey(9),
                                                 (1, 6, 32)))
        y1 = attention(p, x1, n_heads=4, n_kv_heads=2, head_dim=8)
        y2 = attention(p, x2, n_heads=4, n_kv_heads=2, head_dim=8)
        np.testing.assert_allclose(y1[:, :10], y2[:, :10], atol=1e-5)
        assert not np.allclose(y1[:, 10:], y2[:, 10:])

    def test_window_limits_reach(self):
        """With window w, changing a token > w positions back is invisible."""
        p = self._p()
        x1 = jax.random.normal(KEY, (1, 32, 32))
        x2 = x1.at[:, 0].set(0.0)
        y1 = attention(p, x1, n_heads=4, n_kv_heads=2, head_dim=8, window=8)
        y2 = attention(p, x2, n_heads=4, n_kv_heads=2, head_dim=8, window=8)
        np.testing.assert_allclose(y1[:, 16:], y2[:, 16:], atol=1e-5)

    def test_chunked_equals_full(self):
        old = (A.CHUNKED_ABOVE, A.Q_CHUNK)
        try:
            p = self._p()
            x = jax.random.normal(KEY, (2, 64, 32))
            A.CHUNKED_ABOVE, A.Q_CHUNK = 1 << 30, 16
            y_full = attention(p, x, n_heads=4, n_kv_heads=2, head_dim=8,
                               window=20)
            A.CHUNKED_ABOVE = 32
            y_chunk = attention(p, x, n_heads=4, n_kv_heads=2, head_dim=8,
                                window=20)
            np.testing.assert_allclose(y_chunk, y_full, atol=1e-5)
        finally:
            A.CHUNKED_ABOVE, A.Q_CHUNK = old

    def test_gqa_equals_repeated_mha(self):
        """GQA(kv=2) == MHA with kv heads explicitly repeated."""
        p = self._p(kv=2)
        x = jax.random.normal(KEY, (1, 12, 32))
        y = attention(p, x, n_heads=4, n_kv_heads=2, head_dim=8)
        p_mha = dict(p)
        p_mha["wk"] = {"w": jnp.concatenate(
            [p["wk"]["w"].reshape(32, 2, 8)[:, [i // 2]]
             for i in range(4)], axis=1).reshape(32, 32)}
        p_mha["wv"] = {"w": jnp.concatenate(
            [p["wv"]["w"].reshape(32, 2, 8)[:, [i // 2]]
             for i in range(4)], axis=1).reshape(32, 32)}
        y2 = attention(p_mha, x, n_heads=4, n_kv_heads=4, head_dim=8)
        np.testing.assert_allclose(y, y2, atol=1e-5)


class TestDecodeCache:
    def test_decode_matches_forward(self):
        """Token-by-token decode reproduces the full forward pass."""
        p = init_attention(KEY, 32, 4, 2, 8)
        x = jax.random.normal(KEY, (1, 10, 32))
        y_full = attention(p, x, n_heads=4, n_kv_heads=2, head_dim=8)
        cache = init_attn_cache(1, 16, 2, 8, dtype=F32)
        outs = []
        for t in range(10):
            y, cache = attn_decode(p, x[:, t:t + 1], cache,
                                   jnp.asarray(t), n_heads=4, n_kv_heads=2,
                                   head_dim=8)
            outs.append(y)
        np.testing.assert_allclose(jnp.concatenate(outs, 1), y_full,
                                   atol=1e-4)

    def test_ring_cache_matches_window_mask(self):
        """Ring decode (O(w) state) == full cache + window mask."""
        p = init_attention(KEY, 32, 4, 4, 8)
        T, w = 20, 8
        x = jax.random.normal(KEY, (1, T, 32))
        ring = init_attn_cache(1, w, 4, 8, ring=True, dtype=F32)
        full = init_attn_cache(1, T, 4, 8, ring=False, dtype=F32)
        for t in range(T):
            yr, ring = attn_decode(p, x[:, t:t + 1], ring, jnp.asarray(t),
                                   n_heads=4, n_kv_heads=4, head_dim=8,
                                   window=w)
            yf, full = attn_decode(p, x[:, t:t + 1], full, jnp.asarray(t),
                                   n_heads=4, n_kv_heads=4, head_dim=8,
                                   window=w)
            np.testing.assert_allclose(yr, yf, atol=1e-4,
                                       err_msg=f"t={t}")

    def test_ragged_positions(self):
        """Per-slot positions decode independently (continuous batching)."""
        p = init_attention(KEY, 32, 4, 4, 8)
        x = jax.random.normal(KEY, (2, 1, 32))
        # batched with pos [3, 7] == two single-slot decodes
        cb = init_attn_cache(2, 16, 4, 8, dtype=F32)
        cb = type(cb)(k=jax.random.normal(KEY, cb.k.shape),
                      v=jax.random.normal(KEY, cb.v.shape), ring=False)
        yb, _ = attn_decode(p, x, cb, jnp.asarray([3, 7]), n_heads=4,
                            n_kv_heads=4, head_dim=8)
        for i, pos in enumerate([3, 7]):
            ci = type(cb)(k=cb.k[i:i + 1], v=cb.v[i:i + 1], ring=False)
            yi, _ = attn_decode(p, x[i:i + 1], ci, jnp.asarray(pos),
                                n_heads=4, n_kv_heads=4, head_dim=8)
            np.testing.assert_allclose(yb[i:i + 1], yi, atol=1e-5)


class TestMoE:
    def test_output_finite_and_shaped(self):
        cfg = MoEConfig(n_experts=8, top_k=2)
        p = init_moe(KEY, 32, 64, cfg)
        x = jax.random.normal(KEY, (2, 16, 32))
        y = moe_ffn(p, x, cfg)
        assert y.shape == x.shape and bool(jnp.isfinite(y).all())

    def test_capacity_drops_tokens(self):
        """With capacity_factor ≪ 1 overflow tokens are dropped (output
        contribution 0), not corrupted."""
        cfg = MoEConfig(n_experts=2, top_k=1, capacity_factor=0.1)
        p = init_moe(KEY, 16, 32, cfg)
        x = jax.random.normal(KEY, (1, 64, 16))
        y = moe_ffn(p, x, cfg)
        assert bool(jnp.isfinite(y).all())
        # most tokens dropped => many exact-zero rows
        zero_rows = int((jnp.abs(y[0]).max(axis=-1) == 0).sum())
        assert zero_rows >= 32

    def test_top1_equals_dense_single_expert(self):
        """n_experts=1 MoE == its sole expert's SwiGLU."""
        cfg = MoEConfig(n_experts=1, top_k=1, capacity_factor=2.0)
        p = init_moe(KEY, 16, 32, cfg)
        x = jax.random.normal(KEY, (1, 8, 16))
        y = moe_ffn(p, x, cfg)
        h = jax.nn.silu(x @ p["wg"][0]) * (x @ p["wi"][0])
        want = h @ p["wo"][0]
        np.testing.assert_allclose(y, want, atol=1e-5)


class TestSSD:
    def _naive_recurrence(self, x, dt, a_head, B, C):
        """Step-by-step SSM reference: h = e^{aΔ}h + Δ·B⊗x; y = C·h."""
        b, s, h, p = x.shape
        n = B.shape[-1]
        hstate = np.zeros((b, h, p, n))
        ys = np.zeros((b, s, h, p))
        for t in range(s):
            dec = np.exp(np.asarray(dt[:, t]) * np.asarray(a_head))  # [b,h]
            upd = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                            np.asarray(B[:, t]), np.asarray(x[:, t]))
            hstate = dec[:, :, None, None] * hstate + upd
            ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(C[:, t]),
                                 hstate)
        return ys, hstate

    @pytest.mark.parametrize("s,chunk", [(8, 4), (12, 4), (16, 16), (9, 4)])
    def test_chunked_ssd_matches_recurrence(self, s, chunk):
        from repro.models.ssm import _ssd_chunked
        r = np.random.default_rng(0)
        b, h, p, n = 2, 3, 4, 5
        x = jnp.asarray(r.standard_normal((b, s, h, p)), F32)
        dt = jnp.asarray(r.random((b, s, h)) * 0.5 + 0.1, F32)
        a_head = jnp.asarray(-r.random(h) - 0.1, F32)
        B = jnp.asarray(r.standard_normal((b, s, n)), F32)
        C = jnp.asarray(r.standard_normal((b, s, n)), F32)
        y, h_last = _ssd_chunked(x, dt, a_head, B, C, chunk)
        y_ref, h_ref = self._naive_recurrence(x, dt, a_head, B, C)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_last), h_ref, rtol=1e-4,
                                   atol=1e-4)

    def test_decode_matches_forward(self):
        """Single-token SSD decode chain == chunked forward pass."""
        cfg = SSMConfig(d_state=8, expand=2, d_conv=4, headdim=8, chunk=4)
        d_model = 16
        p = init_mamba2(KEY, d_model, cfg)
        x = jax.random.normal(KEY, (1, 12, d_model), F32)
        y_full = mamba2_forward(p, x, d_model, cfg)
        cache = init_ssm_cache(1, d_model, cfg, dtype=F32)
        outs = []
        for t in range(12):
            y, cache = mamba2_decode(p, x[:, t:t + 1], cache, d_model, cfg)
            outs.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(y_full), rtol=2e-3, atol=2e-3)


class TestLayers:
    @given(d=st.sampled_from([8, 32, 128]))
    @settings(deadline=None, max_examples=5)
    def test_rmsnorm_scale_invariance(self, d):
        p = init_rmsnorm(d)
        x = jax.random.normal(KEY, (4, d))
        np.testing.assert_allclose(rmsnorm(p, x), rmsnorm(p, 10.0 * x),
                                   rtol=1e-4, atol=1e-5)

    def test_mlp_shapes(self):
        p = init_mlp(KEY, 16, 64)
        y = mlp(p, jax.random.normal(KEY, (2, 5, 16)))
        assert y.shape == (2, 5, 16)
