"""Sparse substrate: CSR / banked-ELL / ELLPACK / partition / mtx IO."""
import os

import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.sparse import (CSRMatrix, bell_spmv_reference, csr_from_coo,
                          csr_spmv, csr_to_bell, csr_to_dense,
                          diag_dominant_spd, partition_rows, poisson_2d,
                          poisson_3d, random_spd, read_mtx, tridiagonal_spd,
                          write_mtx)
from repro.sparse.ellpack import csr_to_ellpack, ellpack_spmv_reference

FAST = dict(deadline=None, max_examples=15)


class TestCSR:
    def test_coo_roundtrip_with_duplicates(self):
        rows = np.array([0, 0, 1, 0])
        cols = np.array([1, 0, 1, 1])
        vals = np.array([2.0, 1.0, 5.0, 3.0])
        a = csr_from_coo(rows, cols, vals, (2, 2))
        d = csr_to_dense(a)
        np.testing.assert_array_equal(d, [[1.0, 5.0], [0.0, 5.0]])

    def test_diagonal(self):
        a = poisson_2d(8)
        np.testing.assert_array_equal(a.diagonal(), np.full(64, 4.0))

    @given(n=st.integers(4, 64), seed=st.integers(0, 100))
    @settings(**FAST)
    def test_spmv_matches_dense(self, n, seed):
        a = diag_dominant_spd(n, nnz_per_row=6, dominance=1.5, seed=seed)
        x = np.random.default_rng(seed).standard_normal(n)
        np.testing.assert_allclose(csr_spmv(a, x), csr_to_dense(a) @ x,
                                   rtol=1e-12)


class TestGenerators:
    @pytest.mark.parametrize("make,n", [
        (lambda: poisson_2d(12), 144),
        (lambda: poisson_3d(5), 125),
        (lambda: tridiagonal_spd(64), 64),
        (lambda: diag_dominant_spd(80, seed=1), 80),
        (lambda: random_spd(24, seed=1), 24),
    ])
    def test_spd(self, make, n):
        a = make()
        assert a.shape == (n, n)
        d = csr_to_dense(a)
        np.testing.assert_allclose(d, d.T, atol=1e-12)
        w = np.linalg.eigvalsh(d)
        assert w.min() > 0, f"not PD: λmin={w.min()}"

    def test_random_spd_condition(self):
        a = random_spd(32, cond=1e3, seed=0)
        w = np.linalg.eigvalsh(csr_to_dense(a))
        assert w.max() / w.min() == pytest.approx(1e3, rel=0.05)


class TestBell:
    @given(n=st.integers(8, 120), seed=st.integers(0, 50))
    @settings(**FAST)
    def test_bell_spmv_matches(self, n, seed):
        a = diag_dominant_spd(n, nnz_per_row=8, dominance=1.4, seed=seed)
        m = csr_to_bell(a, block_rows=8, col_tile=16)
        x = np.random.default_rng(seed).standard_normal(n)
        np.testing.assert_allclose(bell_spmv_reference(m, x),
                                   csr_to_dense(a) @ x, rtol=1e-10,
                                   atol=1e-10)

    def test_nnz_preserved(self):
        a = poisson_2d(10)
        m = csr_to_bell(a, block_rows=16, col_tile=32)
        assert m.nnz == a.nnz
        assert 0 < m.padding_efficiency <= 1.0

    def test_stream_bytes_ordering(self):
        """Lower precision ⇒ smaller matrix stream (Challenge 3)."""
        a = poisson_2d(10)
        m = csr_to_bell(a, block_rows=16, col_tile=32)
        assert m.stream_bytes(2) < m.stream_bytes(4) < m.stream_bytes(8)


class TestEllpack:
    @given(n=st.integers(8, 150), nnz=st.integers(2, 12),
           seed=st.integers(0, 50))
    @settings(**FAST)
    def test_ellpack_matches_dense(self, n, nnz, seed):
        a = diag_dominant_spd(n, nnz_per_row=nnz, dominance=1.4, seed=seed)
        m = csr_to_ellpack(a, block_rows=8, col_tile=16)
        x = np.random.default_rng(seed).standard_normal(n)
        np.testing.assert_allclose(ellpack_spmv_reference(m, x),
                                   csr_to_dense(a) @ x, rtol=1e-10,
                                   atol=1e-10)

    def test_local_indices_fit_int16(self):
        """The Serpens-style packing claim: local col ids < col_tile."""
        a = poisson_2d(32)
        m = csr_to_ellpack(a, block_rows=128, col_tile=512)
        assert m.local_cols.max() < 512 <= 32768


class TestPartition:
    @pytest.mark.parametrize("n_shards", [1, 2, 4, 7])
    def test_partition_preserves_matrix(self, n_shards):
        a = poisson_2d(12)                       # n=144
        part = partition_rows(a, n_shards, block_rows=8, col_tile=16)
        x = np.random.default_rng(0).standard_normal(144)
        want = csr_to_dense(a) @ x
        got = np.zeros(part.padded_rows)
        for k in range(n_shards):
            sh = part.shard(k)
            xp = x
            got[k * part.rows_per_shard:(k + 1) * part.rows_per_shard] = \
                bell_spmv_reference(sh, xp)
        np.testing.assert_allclose(got[:144], want, rtol=1e-10, atol=1e-10)

    def test_halo_width_stencil(self):
        """Stencil matrices report a narrow halo (enables ppermute)."""
        a = poisson_2d(16)                       # bandwidth 16
        part = partition_rows(a, 4, block_rows=8, col_tile=16)
        assert 0 < part.halo_width <= 16


class TestMtxIO:
    def test_roundtrip(self, tmp_path):
        a = diag_dominant_spd(20, nnz_per_row=4, seed=3)
        p = os.path.join(tmp_path, "m.mtx")
        write_mtx(p, a)
        b = read_mtx(p)
        np.testing.assert_allclose(csr_to_dense(a), csr_to_dense(b),
                                   rtol=1e-12)

    def test_symmetric_storage(self, tmp_path):
        """SuiteSparse symmetric .mtx stores the lower triangle only."""
        a = poisson_2d(4)
        p = os.path.join(tmp_path, "sym.mtx")
        write_mtx(p, a, symmetric=True)
        b = read_mtx(p)
        np.testing.assert_allclose(csr_to_dense(a), csr_to_dense(b),
                                   rtol=1e-12)


class TestStacking:
    """Batched padding/stacking helpers (repro.sparse.stacking)."""

    def _bells(self):
        from repro.sparse import csr_to_bell
        return [csr_to_bell(a, block_rows=8, col_tile=128) for a in
                (poisson_2d(13), tridiagonal_spd(250),
                 diag_dominant_spd(150, nnz_per_row=6, seed=3))]

    def test_pad_bell_preserves_product(self):
        from repro.sparse.stacking import pad_bell
        for m in self._bells():
            big = pad_bell(m, n_row_blocks=m.n_row_blocks + 3,
                           n_slabs=m.n_slabs + 2, slab_len=m.slab_len + 8)
            x = np.random.default_rng(0).standard_normal(m.shape[1])
            np.testing.assert_allclose(bell_spmv_reference(big, x),
                                       bell_spmv_reference(m, x))

    def test_stack_bell_buckets_and_preserves(self):
        from repro.sparse.stacking import bucket_up, stack_bell
        bells = self._bells()
        s = stack_bell(bells)
        assert s.batch == 3
        # every structural dim landed on a power-of-two bucket edge
        for d in s.vals.shape[1:] + (s.n_col_tiles,):
            assert d == bucket_up(d)
        # padding is pure zeros: per-lane nnz mass is preserved
        for g, m in enumerate(bells):
            assert np.count_nonzero(s.vals[g]) == np.count_nonzero(m.vals)

    def test_flatten_bell_stream_matches_csr(self):
        """The packed (col, val, row) stream IS the matrix: scatter-adding
        it reproduces the CSR SpMV."""
        from repro.sparse.stacking import flatten_bell
        for a in (poisson_2d(13), tridiagonal_spd(250)):
            from repro.sparse import csr_to_bell
            m = csr_to_bell(a, block_rows=8, col_tile=128)
            gc, v, rw = flatten_bell(m)
            x = np.random.default_rng(1).standard_normal(m.padded_cols)
            y = np.zeros(m.padded_rows)
            np.add.at(y, rw, v * x[gc])
            np.testing.assert_allclose(y[: a.shape[0]],
                                       csr_spmv(a, x[: a.shape[1]]))

    def test_stack_flat_zero_extension(self):
        """Streams zero-extend to any bucket without changing the product."""
        from repro.sparse.stacking import stack_flat
        bells = self._bells()
        s = stack_flat(bells)
        assert s.gcols.shape == s.vals.shape == s.rows.shape
        for g, m in enumerate(bells):
            x = np.random.default_rng(g).standard_normal(s.padded_cols)
            y = np.zeros(s.padded_rows)
            np.add.at(y, s.rows[g], s.vals[g] * x[s.gcols[g]])
            ref = bell_spmv_reference(m, x[: m.shape[1]])
            np.testing.assert_allclose(y[: m.shape[0]], ref)
