"""Shared test config.

x64 is enabled globally: the paper's faithful tier (FP64 vectors) is
exactly reproducible on CPU.  Model tests pin explicit float32 dtypes, so
they are unaffected by the flag.  Do NOT set
--xla_force_host_platform_device_count here — smoke tests and benches
must see 1 device (multi-device tests spawn subprocesses).
"""
import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
