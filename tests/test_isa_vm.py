"""Stream-centric ISA + VM (paper §3–4): encodings, derived memory
instructions, VM ≡ production solver, no-retrace program swapping."""
import jax
import numpy as np
import pytest

from repro.core.cg import jpcg_solve
from repro.core.isa import (ITYPE_COMP, ITYPE_CTRL, ITYPE_NOP, ITYPE_VCTRL,
                            Instr, assemble_jpcg, derived_mem_instructions,
                            pad_program)
from repro.core.vm import vm_solve
from repro.sparse import poisson_2d, tridiagonal_spd


def test_encoding_roundtrip():
    i = Instr(ITYPE_COMP, f1=3, rd=1, qa=2, qb=4, qd=5, sreg=1)
    w = i.encode()
    assert w == [ITYPE_COMP, 3, 1, 0, 2, 4, 5, 1]
    assert len(w) == 8


def test_program_shape_and_types():
    enc, instrs = assemble_jpcg("paper")
    assert enc.dtype == np.int32 and enc.shape == (len(instrs), 8)
    assert set(enc[:, 0]) <= {ITYPE_VCTRL, ITYPE_COMP, ITYPE_CTRL, ITYPE_NOP}


@pytest.mark.parametrize("policy,reads,writes", [("paper", 10, 4),
                                                 ("min_traffic", 9, 4)])
def test_derived_memory_instructions_match_vsr(policy, reads, writes):
    """§4.1.3: Type-III InstRdWr stream == the §5.5 accounting."""
    enc, _ = assemble_jpcg(policy)
    m = derived_mem_instructions(enc)
    assert m == {"reads": reads, "writes": writes,
                 "total": reads + writes}


def test_derived_mem_instructions_regression_lock():
    """Regression lock (§4.1.3): the paper program's derived Type-III
    InstRdWr stream is EXACTLY 10 reads + 4 writes — the ISA-level twin
    of the §5.5 VSR accounting lock in test_vsr.py.  A drift here means
    assemble_jpcg emits a different memory schedule."""
    enc, _ = assemble_jpcg("paper")
    m = derived_mem_instructions(enc)
    assert m == {"reads": 10, "writes": 4, "total": 14}
    enc2, _ = assemble_jpcg("min_traffic")
    m2 = derived_mem_instructions(enc2)
    assert m2 == {"reads": 9, "writes": 4, "total": 13}
    # min_traffic saves exactly one read vs the paper schedule
    assert m["reads"] - m2["reads"] == 1 and m["writes"] == m2["writes"]


@pytest.mark.parametrize("policy", ["paper", "min_traffic"])
def test_vm_matches_production_solver(policy):
    """Executing the ISA program reproduces the phase-fused solver
    exactly (same iterate path ⇒ same iteration count and residual)."""
    a = poisson_2d(24)
    prog, _ = assemble_jpcg(policy)
    out = vm_solve(a, program=prog, tol=1e-12, maxiter=3000,
                   scheme="mixed_v3", block_rows=64, col_tile=128)
    ref = jpcg_solve(a, tol=1e-12, maxiter=3000, scheme="mixed_v3",
                     block_rows=64, col_tile=128)
    assert out["iterations"] == ref.iterations
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(ref.x),
                               rtol=1e-10, atol=1e-12)


def test_nop_padding_preserves_semantics():
    """NOP-padded programs (shared compiled VM across policies) solve
    identically — the paper's 'no re-synthesis per problem' goal."""
    a = tridiagonal_spd(512)
    p1, _ = assemble_jpcg("paper")
    p2, _ = assemble_jpcg("min_traffic")
    length = max(p1.shape[0], p2.shape[0])
    o1 = vm_solve(a, program=pad_program(p1, length), tol=1e-12,
                  maxiter=2000, block_rows=64, col_tile=128)
    o2 = vm_solve(a, program=pad_program(p2, length), tol=1e-12,
                  maxiter=2000, block_rows=64, col_tile=128)
    assert o1["iterations"] == o2["iterations"]
    np.testing.assert_allclose(np.asarray(o1["x"]), np.asarray(o2["x"]),
                               rtol=1e-10)


def test_program_is_operand_not_trace_constant():
    """Same padded length ⇒ one compiled executable for both programs.

    The VM executable is cached per bucket (``vm_executable_stats``
    counts jit trace entries across all cached VM runners/steppers);
    swapping the program operand must not add a trace.
    """
    from repro.core.vm import vm_executable_stats
    a = tridiagonal_spd(256)
    p1, _ = assemble_jpcg("paper")
    p2, _ = assemble_jpcg("min_traffic")
    L = max(p1.shape[0], p2.shape[0])
    n_before = vm_executable_stats()["traces"]
    vm_solve(a, program=pad_program(p1, L), tol=1e-12, maxiter=100,
             block_rows=64, col_tile=128)
    n_mid = vm_executable_stats()["traces"]
    vm_solve(a, program=pad_program(p2, L), tol=1e-12, maxiter=100,
             block_rows=64, col_tile=128)
    n_after = vm_executable_stats()["traces"]
    assert n_mid == n_before + 1
    assert n_after == n_mid              # second program: no retrace


def test_pad_program_rejects_truncation():
    enc, _ = assemble_jpcg("paper")
    with pytest.raises(ValueError):
        pad_program(enc, enc.shape[0] - 1)
