"""Stream-centric ISA + VM (paper §3–4): encodings, derived memory
instructions, VM ≡ production solver, no-retrace program swapping."""
import jax
import numpy as np
import pytest

from repro.core.cg import jpcg_solve
from repro.core.isa import (ITYPE_COMP, ITYPE_CTRL, ITYPE_NOP, ITYPE_VCTRL,
                            Instr, assemble_jpcg, derived_mem_instructions,
                            pad_program)
from repro.core.vm import vm_solve
from repro.sparse import poisson_2d, tridiagonal_spd
from oracles import assert_vm_states_equal


def test_encoding_roundtrip():
    i = Instr(ITYPE_COMP, f1=3, rd=1, qa=2, qb=4, qd=5, sreg=1)
    w = i.encode()
    assert w == [ITYPE_COMP, 3, 1, 0, 2, 4, 5, 1]
    assert len(w) == 8


def test_program_shape_and_types():
    enc, instrs = assemble_jpcg("paper")
    assert enc.dtype == np.int32 and enc.shape == (len(instrs), 8)
    assert set(enc[:, 0]) <= {ITYPE_VCTRL, ITYPE_COMP, ITYPE_CTRL, ITYPE_NOP}


@pytest.mark.parametrize("policy,reads,writes", [("paper", 10, 4),
                                                 ("min_traffic", 9, 4)])
def test_derived_memory_instructions_match_vsr(policy, reads, writes):
    """§4.1.3: Type-III InstRdWr stream == the §5.5 accounting."""
    enc, _ = assemble_jpcg(policy)
    m = derived_mem_instructions(enc)
    assert m == {"reads": reads, "writes": writes,
                 "total": reads + writes}


def test_derived_mem_instructions_regression_lock():
    """Regression lock (§4.1.3): the paper program's derived Type-III
    InstRdWr stream is EXACTLY 10 reads + 4 writes — the ISA-level twin
    of the §5.5 VSR accounting lock in test_vsr.py.  A drift here means
    assemble_jpcg emits a different memory schedule."""
    enc, _ = assemble_jpcg("paper")
    m = derived_mem_instructions(enc)
    assert m == {"reads": 10, "writes": 4, "total": 14}
    enc2, _ = assemble_jpcg("min_traffic")
    m2 = derived_mem_instructions(enc2)
    assert m2 == {"reads": 9, "writes": 4, "total": 13}
    # min_traffic saves exactly one read vs the paper schedule
    assert m["reads"] - m2["reads"] == 1 and m["writes"] == m2["writes"]


@pytest.mark.parametrize("policy", ["paper", "min_traffic"])
def test_vm_matches_production_solver(policy):
    """Executing the ISA program reproduces the phase-fused solver
    exactly (same iterate path ⇒ same iteration count and residual)."""
    a = poisson_2d(24)
    prog, _ = assemble_jpcg(policy)
    out = vm_solve(a, program=prog, tol=1e-12, maxiter=3000,
                   scheme="mixed_v3", block_rows=64, col_tile=128)
    ref = jpcg_solve(a, tol=1e-12, maxiter=3000, scheme="mixed_v3",
                     block_rows=64, col_tile=128)
    assert out["iterations"] == ref.iterations
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(ref.x),
                               rtol=1e-10, atol=1e-12)


def test_nop_padding_preserves_semantics():
    """NOP-padded programs (shared compiled VM across policies) solve
    identically — the paper's 'no re-synthesis per problem' goal."""
    a = tridiagonal_spd(512)
    p1, _ = assemble_jpcg("paper")
    p2, _ = assemble_jpcg("min_traffic")
    length = max(p1.shape[0], p2.shape[0])
    o1 = vm_solve(a, program=pad_program(p1, length), tol=1e-12,
                  maxiter=2000, block_rows=64, col_tile=128)
    o2 = vm_solve(a, program=pad_program(p2, length), tol=1e-12,
                  maxiter=2000, block_rows=64, col_tile=128)
    assert o1["iterations"] == o2["iterations"]
    np.testing.assert_allclose(np.asarray(o1["x"]), np.asarray(o2["x"]),
                               rtol=1e-10)


def test_program_is_operand_not_trace_constant():
    """Same padded length ⇒ one compiled executable for both programs.

    On the *generic* path (``specialize=False``) the VM executable is
    cached per bucket (``vm_executable_stats`` counts jit trace entries
    across all cached VM runners/steppers); swapping the program operand
    must not add a trace.  (The default *specialized* path keys on
    program bytes by design — see tests/test_compile.py.)
    """
    from repro.core.vm import vm_executable_stats
    a = tridiagonal_spd(256)
    p1, _ = assemble_jpcg("paper")
    p2, _ = assemble_jpcg("min_traffic")
    L = max(p1.shape[0], p2.shape[0])
    n_before = vm_executable_stats()["traces"]
    vm_solve(a, program=pad_program(p1, L), tol=1e-12, maxiter=100,
             block_rows=64, col_tile=128, specialize=False)
    n_mid = vm_executable_stats()["traces"]
    vm_solve(a, program=pad_program(p2, L), tol=1e-12, maxiter=100,
             block_rows=64, col_tile=128, specialize=False)
    n_after = vm_executable_stats()["traces"]
    assert n_mid == n_before + 1
    assert n_after == n_mid              # second program: no retrace


# ------------------------------------------------ stepper state handling
def _vm_operands(probs, tol, scheme="mixed_v3"):
    """Replicate jpcg_solve_batched's xla operand packing (row-ELL) so
    runner / stepper state handling can be tested below the batch API.
    ``bk`` holds the runner kwargs; steppers additionally need the
    bucket dims — ``mat[0].shape[1:]`` (= slot-major row width, padded
    rows).  Values arrive packed at the scheme's at-rest matrix dtype."""
    import jax.numpy as jnp

    from repro.core.precision import get_scheme
    from repro.sparse.stacking import stack_rowell
    sch = get_scheme(scheme)
    stacked = stack_rowell(list(probs), bucket=True, scheme=sch)
    mat = (jnp.asarray(stacked.cols), jnp.asarray(stacked.vals))
    vd = sch.vector_dtype
    G, n_pad = len(probs), stacked.padded_rows
    diag = np.ones((G, n_pad))
    b = np.zeros((G, n_pad))
    for g, a in enumerate(probs):
        n = a.shape[0]
        diag[g, :n] = a.diagonal()
        b[g, :n] = 1.0
    bk = dict(backend="xla", scheme=scheme)
    return (mat, jnp.asarray(diag, vd), jnp.asarray(b, vd),
            jnp.zeros((G, n_pad), vd), jnp.full(G, tol, vd), bk)


@pytest.mark.vm
@pytest.mark.parametrize("specialize", [True, False])
def test_stepper_past_trace_width_cannot_clobber_trace(specialize):
    """Behavior lock (ISSUE 6): continuing a with-trace state through
    the stepper beyond its trace width must leave the trace alone — and
    the continued state must stay bit-identical to an uninterrupted run.
    The unguarded write only survived out-of-range ticks because JAX
    silently DROPS out-of-bounds scatter updates; the explicit guard in
    ``_masked_trace`` pins that behavior down instead of leaning on it."""
    import jax.numpy as jnp

    from repro.core.compile import canonical_program
    from repro.core.vm import make_vm_runner, make_vm_stepper
    prog = canonical_program("paper")
    W = 6
    mat, diag, b, x0, tolv, bk = _vm_operands(
        [tridiagonal_spd(200)], tol=1e-30)      # tiny tol: never converges
    if specialize:
        st = make_vm_runner(program=prog, maxiter=W, with_trace=True,
                            **bk)(mat, diag, b, x0, tolv)
    else:
        st = make_vm_runner(maxiter=W, with_trace=True, **bk)(
            jnp.asarray(prog), mat, diag, b, x0, tolv)
    assert int(st.k) == W and st.trace.shape == (1, W)

    stepper = make_vm_stepper(
        chunk=10, bucket=tuple(mat[0].shape[1:]),
        program=prog if specialize else None, **bk)
    mv = jnp.full(1, 20, jnp.int32)
    for _ in range(2):                           # k: 6 -> 16 -> 20
        if specialize:
            st = stepper(mat, st, tolv, mv)
        else:
            st = stepper(jnp.asarray(prog), mat, st, tolv, mv)
    assert int(st.it[0]) == 20

    # An uninterrupted 20-iteration run is the oracle: the continued
    # state must bit-match it, and the narrow trace must still hold
    # iterations 0..W-1 (NOT the clamped overwrite of the last column).
    ref = make_vm_runner(program=prog, maxiter=20, with_trace=True,
                         **bk)(mat, diag, b, x0, tolv)
    assert np.array_equal(np.asarray(st.mem), np.asarray(ref.mem))
    assert np.array_equal(np.asarray(st.sregs), np.asarray(ref.sregs))
    assert np.array_equal(np.asarray(st.trace),
                          np.asarray(ref.trace[:, :W]))


@pytest.mark.vm
@pytest.mark.parametrize("specialize", [True, False])
def test_frozen_lane_state_is_bit_stable_through_stepper(specialize):
    """Regression (ISSUE 6): a converged lane's ENTIRE state — mem,
    queues, sregs — must freeze while other lanes keep stepping.  The
    queue file used to be written unmasked, so a frozen lane's streams
    took one more unmasked rewrite after its final (converging) tick —
    ``chunk=1`` pins the snapshot to that exact tick, where the drift
    is observable."""
    import jax.numpy as jnp

    from repro.core.compile import canonical_program
    from repro.core.vm import make_vm_runner, make_vm_stepper
    prog = canonical_program("paper")
    easy, hard = tridiagonal_spd(128, off=-0.1), tridiagonal_spd(256)
    mat, diag, b, x0, tolv, bk = _vm_operands([easy, hard], tol=1e-12)
    if specialize:
        st = make_vm_runner(program=prog, maxiter=0, with_trace=False,
                            **bk)(mat, diag, b, x0, tolv)
    else:
        st = make_vm_runner(maxiter=0, with_trace=False, **bk)(
            jnp.asarray(prog), mat, diag, b, x0, tolv)
    stepper = make_vm_stepper(
        chunk=1, bucket=tuple(mat[0].shape[1:]),
        program=prog if specialize else None, **bk)
    mv = jnp.full(2, 1000, jnp.int32)

    def step(s):
        if specialize:
            return stepper(mat, s, tolv, mv)
        return stepper(jnp.asarray(prog), mat, s, tolv, mv)

    while bool(st.active[0]) and bool(st.active[1]):
        st = step(st)
    frozen = 0 if not bool(st.active[0]) else 1
    assert bool(st.active[1 - frozen]), "need one live + one frozen lane"
    snap = {f: np.asarray(getattr(st, f))
            for f in ("mem", "queues", "sregs", "it")}
    st2 = step(st)
    assert int(st2.k) > int(st.k)                # the live lane advanced
    assert_vm_states_equal(st2, snap, lane=frozen)


@pytest.mark.vm
@pytest.mark.parametrize("specialize", [True, False])
def test_stepper_chunk_sizes_bit_identical(specialize):
    """ISSUE 7: ``steps_per_sync`` (in-chunk iterations per termination
    sync) must be invisible in every observable — final mem, queues,
    sregs, it, k bit-identical across k ∈ {1, 4, 8}, including a lane
    that freezes mid-chunk (the easy lane) while the other keeps going."""
    import jax.numpy as jnp

    from repro.core.compile import canonical_program
    from repro.core.vm import make_vm_runner, make_vm_stepper
    prog = canonical_program("paper")
    easy, hard = tridiagonal_spd(128, off=-0.1), tridiagonal_spd(256)
    mat, diag, b, x0, tolv, bk = _vm_operands([easy, hard], tol=1e-12)
    mv = jnp.full(2, 1000, jnp.int32)

    def boot():
        if specialize:
            return make_vm_runner(program=prog, maxiter=0,
                                  with_trace=False, **bk)(
                mat, diag, b, x0, tolv)
        return make_vm_runner(maxiter=0, with_trace=False, **bk)(
            jnp.asarray(prog), mat, diag, b, x0, tolv)

    finals = {}
    for sps in (1, 4, 8):
        stepper = make_vm_stepper(
            chunk=8, bucket=tuple(mat[0].shape[1:]), steps_per_sync=sps,
            program=prog if specialize else None, **bk)
        st = boot()
        while bool(st.active.any()):
            if specialize:
                st = stepper(mat, st, tolv, mv)
            else:
                st = stepper(jnp.asarray(prog), mat, st, tolv, mv)
        finals[sps] = st
    ref = finals[1]
    for sps in (4, 8):
        st = finals[sps]
        assert int(st.k) == int(ref.k)
        assert_vm_states_equal(st, ref)


@pytest.mark.vm
@pytest.mark.parametrize("specialize", [True, False])
def test_donating_stepper_consumes_input_state(specialize):
    """ISSUE 7: ``donate=True`` really donates — the state passed in is
    deleted by the call (its buffers are aliased into the output), so a
    caller holding device references across the step reads garbage.
    This is the contract that forces :meth:`_Pool.harvest` to
    materialize results to host before the next step."""
    import jax.numpy as jnp

    from repro.core.compile import canonical_program
    from repro.core.vm import make_vm_runner, make_vm_stepper
    prog = canonical_program("paper")
    mat, diag, b, x0, tolv, bk = _vm_operands(
        [tridiagonal_spd(200)], tol=1e-12)
    if specialize:
        st = make_vm_runner(program=prog, maxiter=0, with_trace=False,
                            **bk)(mat, diag, b, x0, tolv)
    else:
        st = make_vm_runner(maxiter=0, with_trace=False, **bk)(
            jnp.asarray(prog), mat, diag, b, x0, tolv)
    stepper = make_vm_stepper(
        chunk=4, bucket=tuple(mat[0].shape[1:]), donate=True,
        program=prog if specialize else None, **bk)
    mv = jnp.full(1, 1000, jnp.int32)
    if specialize:
        st2 = stepper(mat, st, tolv, mv)
    else:
        st2 = stepper(jnp.asarray(prog), mat, st, tolv, mv)
    assert int(st2.k) == 4                       # the step itself worked
    with pytest.raises(RuntimeError):
        np.asarray(st.mem)                       # donated: deleted

    # ... and donation changes nothing observable: a fresh boot stepped
    # without donation lands on the bit-identical state.
    plain = make_vm_stepper(
        chunk=4, bucket=tuple(mat[0].shape[1:]), donate=False,
        program=prog if specialize else None, **bk)
    if specialize:
        st0 = make_vm_runner(program=prog, maxiter=0, with_trace=False,
                             **bk)(mat, diag, b, x0, tolv)
        st3 = plain(mat, st0, tolv, mv)
    else:
        st0 = make_vm_runner(maxiter=0, with_trace=False, **bk)(
            jnp.asarray(prog), mat, diag, b, x0, tolv)
        st3 = plain(jnp.asarray(prog), mat, st0, tolv, mv)
    assert_vm_states_equal(st2, st3)


def test_pad_program_rejects_truncation():
    enc, _ = assemble_jpcg("paper")
    with pytest.raises(ValueError):
        pad_program(enc, enc.shape[0] - 1)
