"""Hypothesis shim — property tests with or without ``hypothesis``.

The property-test modules import ``given``/``settings``/``strategies``
from here instead of from ``hypothesis`` directly.  When the real
package is installed we re-export it untouched (full shrinking, the
works).  When it is absent (minimal CI images, the baked container),
we fall back to *fixed example sampling*: each ``@given`` test runs
``max_examples`` times against examples drawn from a deterministic
per-test RNG (seeded from the test's qualified name), so runs are
reproducible and a failure names the exact drawn values.

Supported strategy surface (what the suite uses):
``st.integers(lo, hi)``, ``st.floats(lo, hi)``, ``st.sampled_from(seq)``,
``st.booleans()``.  ``settings(...)`` honors ``max_examples`` and ignores
``deadline``/``derandomize`` (meaningless without the real engine).
"""
from __future__ import annotations

__all__ = ["given", "settings", "strategies", "HAVE_HYPOTHESIS"]

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def example(self, rng: np.random.Generator):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = float(min_value), float(max_value)

        def example(self, rng):
            return float(self.lo + (self.hi - self.lo) * rng.random())

    class _SampledFrom(_Strategy):
        def __init__(self, seq):
            self.seq = list(seq)

        def example(self, rng):
            return self.seq[int(rng.integers(0, len(self.seq)))]

    class _Booleans(_Strategy):
        def example(self, rng):
            return bool(rng.integers(0, 2))

    class _StrategiesModule:
        """Duck-typed stand-in for ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(seq):
            return _SampledFrom(seq)

        @staticmethod
        def booleans():
            return _Booleans()

    strategies = _StrategiesModule()

    def settings(**kw):
        """Record settings on the test function (or on a @given wrapper)."""

        def deco(fn):
            fn._hyp_settings = kw
            return fn

        return deco

    def given(**strats):
        """Run the test over deterministic examples of each strategy.

        The wrapper deliberately does NOT expose ``__wrapped__``: pytest
        introspects it for fixture names, and the strategy parameters
        must stay invisible to the fixture machinery (the real
        hypothesis pulls the same trick).
        """
        for k, v in strats.items():
            if not isinstance(v, _Strategy):
                raise TypeError(f"unsupported strategy for {k!r}: {v!r}")

        def deco(fn):
            def wrapper(*args, **kwargs):
                n = {**getattr(fn, "_hyp_settings", {}),
                     **getattr(wrapper, "_hyp_settings", {})}.get(
                    "max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i} (seed={seed}): "
                            f"{drawn!r}") from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._hyp_settings = getattr(fn, "_hyp_settings", {})
            return wrapper

        return deco
