"""Activation-hint machinery: no-op without a mesh, axis resolution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import hints


def test_noop_without_context():
    x = jnp.ones((4, 8))
    y = hints.hint(x, hints.DATA, hints.MODEL)
    assert y is x                      # literally untouched


def test_resolution_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with hints.sharding_hints(mesh):
        assert hints.active_mesh() is mesh
        x = jnp.arange(8.0).reshape(2, 4)
        y = hints.hint(x, hints.DATA, hints.MODEL)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert hints.active_mesh() is None


def test_missing_axes_dropped():
    mesh = jax.make_mesh((1,), ("rows",))   # no data/model axes
    with hints.sharding_hints(mesh):
        x = jnp.ones((4, 4))
        y = hints.hint(x, hints.DATA, hints.MODEL)
        assert y is x                  # all entries resolved to None


def test_context_nesting_restores():
    mesh = jax.make_mesh((1,), ("rows",))
    with hints.sharding_hints(mesh):
        with hints.sharding_hints(None):
            assert hints.active_mesh() is None
        assert hints.active_mesh() is mesh


def test_hint_inside_jit_traces():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def f(x):
        return hints.hint(x, hints.DATA, None) * 2.0

    with hints.sharding_hints(mesh):
        y = jax.jit(f)(jnp.ones((2, 2)))
    np.testing.assert_array_equal(np.asarray(y), 2.0)
