"""Gauss–Newton bridge: GGN operator properties + CGGN optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gn import estimate_jacobi_diag, flatten_like, make_ggn_matvec
from repro.train import CGGNConfig, cggn_init, cggn_update


def _linear_problem(key, n_in=6, n_out=4, n_data=32):
    """Least squares: logits = X·W; loss = ½‖logits − Y‖²."""
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n_data, n_in))
    Y = jax.random.normal(k2, (n_data, n_out))
    W0 = jax.random.normal(k3, (n_in, n_out)) * 0.1
    params = {"w": W0}

    def logits_fn(p):
        return X @ p["w"]

    def loss_logits(lg):
        return 0.5 * jnp.sum((lg - Y) ** 2) / n_data

    return params, logits_fn, loss_logits, X, Y


class TestFlatten:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones(4)}}
        flat, ravel, unravel = flatten_like(tree)
        assert flat.shape == (10,)
        back = unravel(flat)
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestGGNOperator:
    def test_matches_explicit_ggn(self):
        """Matrix-free G·v == XᵀX/n·v for the linear least-squares case."""
        params, logits_fn, loss_logits, X, Y = _linear_problem(
            jax.random.PRNGKey(0))
        damping = 1e-3
        mv, n = make_ggn_matvec(loss_logits, logits_fn, params, damping)
        n_in, n_out = 6, 4
        assert n == n_in * n_out
        G = np.kron(np.asarray(X.T @ X) / 32, np.eye(n_out))
        v = np.random.default_rng(0).standard_normal(n)
        got = np.asarray(mv(jnp.asarray(v)))
        want = G @ v + damping * v
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_spd(self):
        """G + λI is symmetric positive definite (CG's precondition)."""
        params, logits_fn, loss_logits, *_ = _linear_problem(
            jax.random.PRNGKey(1))
        mv, n = make_ggn_matvec(loss_logits, logits_fn, params, 1e-3)
        rng = np.random.default_rng(1)
        M = np.stack([np.asarray(mv(jnp.asarray(np.eye(n)[i])))
                      for i in range(n)])
        np.testing.assert_allclose(M, M.T, atol=1e-5)
        assert np.linalg.eigvalsh(M).min() > 0

    def test_hutchinson_diag(self):
        params, logits_fn, loss_logits, *_ = _linear_problem(
            jax.random.PRNGKey(2))
        mv, n = make_ggn_matvec(loss_logits, logits_fn, params, 1e-3)
        M = np.stack([np.asarray(mv(jnp.asarray(np.eye(n)[i])))
                      for i in range(n)])
        est = np.asarray(estimate_jacobi_diag(mv, n, jax.random.PRNGKey(3),
                                              probes=256))
        np.testing.assert_allclose(est, np.diag(M), rtol=0.5)
        assert est.min() > 0


class TestCGGN:
    def test_one_step_solves_linear_least_squares(self):
        """GN == Newton on quadratics: one CGGN step with enough CG
        iterations lands at the optimum."""
        params, logits_fn, loss_logits, X, Y = _linear_problem(
            jax.random.PRNGKey(4))

        def vag(p):
            return jax.value_and_grad(
                lambda q: loss_logits(logits_fn(q)))(p)

        cfg = CGGNConfig(lr=1.0, damping=1e-6, cg_iters=200, cg_tol=1e-18,
                         probes=8, scheme="tpu_fp32")
        st = cggn_init(params, jax.random.PRNGKey(5))
        p1, st, m1 = cggn_update(params, st, loss_logits_fn=loss_logits,
                                 logits_fn=logits_fn,
                                 loss_value_and_grad=vag, cfg=cfg)
        w_star = np.linalg.lstsq(np.asarray(X), np.asarray(Y), rcond=None)[0]
        np.testing.assert_allclose(np.asarray(p1["w"]), w_star, rtol=1e-2,
                                   atol=1e-3)

    def test_loss_decreases_on_mlp(self):
        """CGGN makes monotone progress on a small nonlinear model."""
        key = jax.random.PRNGKey(6)
        X = jax.random.normal(key, (64, 8))
        Y = jnp.sin(X @ jax.random.normal(jax.random.PRNGKey(7), (8, 3)))
        params = {"w1": jax.random.normal(key, (8, 16)) * 0.3,
                  "w2": jax.random.normal(key, (16, 3)) * 0.3}

        def logits_fn(p):
            return jnp.tanh(X @ p["w1"]) @ p["w2"]

        def loss_logits(lg):
            return 0.5 * jnp.mean((lg - Y) ** 2)

        def vag(p):
            return jax.value_and_grad(
                lambda q: loss_logits(logits_fn(q)))(p)

        cfg = CGGNConfig(lr=1.0, damping=1e-2, cg_iters=30,
                         scheme="tpu_fp32")
        st = cggn_init(params, jax.random.PRNGKey(8))
        losses = []
        for _ in range(5):
            params, st, m = cggn_update(params, st,
                                        loss_logits_fn=loss_logits,
                                        logits_fn=logits_fn,
                                        loss_value_and_grad=vag, cfg=cfg)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.5, losses

    def test_precond_refresh_cadence(self):
        params, logits_fn, loss_logits, *_ = _linear_problem(
            jax.random.PRNGKey(9))

        def vag(p):
            return jax.value_and_grad(
                lambda q: loss_logits(logits_fn(q)))(p)

        cfg = CGGNConfig(refresh_precond=2, cg_iters=5, scheme="tpu_fp32")
        st = cggn_init(params, jax.random.PRNGKey(10))
        _, st1, _ = cggn_update(params, st, loss_logits_fn=loss_logits,
                                logits_fn=logits_fn,
                                loss_value_and_grad=vag, cfg=cfg)
        d1 = np.asarray(st1.diag)
        assert not np.allclose(d1, 1.0)          # refreshed at step 0
