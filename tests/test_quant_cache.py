"""Int8 KV cache (Mix-V3 one tier further): accuracy vs bf16 reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attn_decode, init_attention,
                                    init_attn_cache)
from repro.serve.quant_cache import (attn_decode_quant, dequantize_kv,
                                     init_quant_cache, quantize_kv)

KEY = jax.random.PRNGKey(0)


class TestQuantPrimitives:
    def test_roundtrip_error_bound(self):
        x = jax.random.normal(KEY, (4, 8, 64)) * 3.0
        q, s = quantize_kv(x)
        back = dequantize_kv(q, s)
        # absmax/127 per row bounds the elementwise error at scale/2
        err = np.abs(np.asarray(back) - np.asarray(x))
        bound = np.asarray(s)[..., None] * 0.5 + 1e-6
        assert (err <= bound).all()

    def test_scale_positive(self):
        q, s = quantize_kv(jnp.zeros((2, 3, 16)))
        assert (np.asarray(s) > 0).all()
        assert (np.asarray(q) == 0).all()


class TestQuantDecode:
    def _roll(self, window=None, steps=24, ring=False):
        n_heads, n_kv, hd, d = 4, 2, 16, 64
        p = init_attention(KEY, d, n_heads, n_kv, hd)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, steps, d))
        length = window if ring else steps
        ref = init_attn_cache(2, length, n_kv, hd, ring=ring,
                              dtype=jnp.float32)
        qc = init_quant_cache(2, length, n_kv, hd, ring=ring)
        outs_ref, outs_q = [], []
        for t in range(steps):
            yr, ref = attn_decode(p, x[:, t:t + 1], ref, jnp.asarray(t),
                                  n_heads=n_heads, n_kv_heads=n_kv,
                                  head_dim=hd, window=window)
            yq, qc = attn_decode_quant(p, x[:, t:t + 1], qc,
                                       jnp.asarray(t), n_heads=n_heads,
                                       n_kv_heads=n_kv, head_dim=hd,
                                       window=window)
            outs_ref.append(yr)
            outs_q.append(yq)
        return (np.asarray(jnp.concatenate(outs_ref, 1)),
                np.asarray(jnp.concatenate(outs_q, 1)))

    def test_full_cache_close(self):
        yr, yq = self._roll()
        denom = np.abs(yr).max() + 1e-6
        assert np.abs(yr - yq).max() / denom < 0.05, \
            np.abs(yr - yq).max() / denom

    def test_ring_cache_close(self):
        yr, yq = self._roll(window=8, ring=True)
        denom = np.abs(yr).max() + 1e-6
        assert np.abs(yr - yq).max() / denom < 0.05

    def test_cache_is_half_the_bytes(self):
        full = init_attn_cache(4, 128, 2, 64, dtype=jnp.bfloat16)
        quant = init_quant_cache(4, 128, 2, 64)
        fb = sum(a.size * a.dtype.itemsize
                 for a in jax.tree_util.tree_leaves(full))
        qb = sum(a.size * a.dtype.itemsize
                 for a in jax.tree_util.tree_leaves(quant))
        # int8 payload + f32 scales ≈ 0.53× of bf16
        assert qb < 0.6 * fb

    def test_argmax_agreement_end_to_end(self):
        """Greedy decode path: int8 cache picks the same tokens as fp32
        attention for a small model rollout."""
        from repro.models import init_params
        from repro.models.config import ModelConfig
        from repro.models.layers import norm, unembed, embed, ffn

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                          head_dim=16, dtype="float32", remat=False)
        params = init_params(cfg, KEY)

        def step(caches, tok, pos, quant):
            x = embed(params["embed"], tok[:, None], jnp.float32)
            new = []
            for l in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a: a[l],
                                            params["layers"])
                u = norm(lp["ln1"], x, cfg.norm_eps)
                if quant:
                    y, c = attn_decode_quant(
                        lp["attn"], u, caches[l], pos, n_heads=4,
                        n_kv_heads=2, head_dim=16)
                else:
                    y, c = attn_decode(
                        lp["attn"], u, caches[l], pos, n_heads=4,
                        n_kv_heads=2, head_dim=16)
                new.append(c)
                x = x + y
                x = x + ffn(lp["mlp"], norm(lp["ln2"], x, cfg.norm_eps))
            x = norm(params["ln_f"], x, cfg.norm_eps)
            return new, unembed(params["embed"], x)[:, 0]

        def rollout(quant):
            if quant:
                caches = [init_quant_cache(1, 32, 2, 16)
                          for _ in range(cfg.n_layers)]
            else:
                caches = [init_attn_cache(1, 32, 2, 16, dtype=jnp.float32)
                          for _ in range(cfg.n_layers)]
            tok = jnp.asarray([7])
            out = []
            for t in range(12):
                caches, logits = step(caches, tok, jnp.asarray(t), quant)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(int(tok[0]))
            return out

        assert rollout(False) == rollout(True)
