"""Shared bit-identity oracle assertions (ISSUE 10).

Every variant axis in this suite — engine (phases vs generic VM vs
specialized VM), layout (row-ELL vs sliced-ELL), backend (XLA vs
Pallas), iteration chunking, donation, and now lane sharding — is held
to the same standard: **bitwise** agreement with a reference run, not
"close enough".  These helpers are the one place that standard is
written down; test modules import them instead of re-rolling ad-hoc
``np.array_equal`` loops so a strengthened check strengthens every
caller at once.

Conventions:

* ``equal_nan=True`` everywhere — a poisoned (non-finite) lane must be
  *identically* poisoned in both runs; mismatched NaN placement still
  fails because ``array_equal`` compares element-wise positions.
* lane indices appear in every failure message, so a 16-lane sweep
  failing on lane 11 says so.
"""
import numpy as np

__all__ = [
    "assert_lane_equal",
    "assert_results_bit_identical",
    "assert_statuses",
    "assert_vm_states_equal",
]


def assert_lane_equal(r1, r2, g=None, *, rr=False, trace=False,
                      status=False):
    """One lane's result equals another, bitwise.

    Always checks ``iterations`` and ``x``; opt into ``rr`` (final
    squared residual), ``residual_trace`` and ``status`` where the
    caller's contract covers them.
    """
    tag = "" if g is None else f"lane {g}: "
    assert r1.iterations == r2.iterations, (
        f"{tag}iterations differ: {r1.iterations} != {r2.iterations}")
    if status:
        assert r1.status == r2.status, (
            f"{tag}status differs: {r1.status} != {r2.status}")
    if rr:
        assert np.array_equal(np.asarray(r1.rr), np.asarray(r2.rr),
                              equal_nan=True), (
            f"{tag}rr differs: {r1.rr} != {r2.rr}")
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x),
                          equal_nan=True), f"{tag}x differs"
    if trace:
        assert np.array_equal(np.asarray(r1.residual_trace),
                              np.asarray(r2.residual_trace),
                              equal_nan=True), (
            f"{tag}residual trace differs")


def assert_results_bit_identical(got, ref, **lane_kw):
    """Two result sequences agree lane-for-lane (see assert_lane_equal;
    keyword options are forwarded per lane)."""
    assert len(got) == len(ref), (
        f"result counts differ: {len(got)} != {len(ref)}")
    for g, (r, r0) in enumerate(zip(got, ref)):
        assert_lane_equal(r, r0, g, **lane_kw)


def assert_statuses(results, expected, *, healthy=(), maxiter=None):
    """Structured-exit oracle: lanes in ``expected`` (index -> status
    string) terminated with exactly that diagnosis, did not claim
    convergence, and — when ``maxiter`` is given — froze before
    spinning out the budget; lanes in ``healthy`` CONVERGED."""
    for g, want in expected.items():
        r = results[g]
        assert r.status == want, f"lane {g}: {r.status} != {want}"
        assert not r.converged, f"lane {g}: {want} but converged"
        if maxiter is not None:
            assert r.iterations < maxiter, (
                f"lane {g}: froze late ({r.iterations} >= {maxiter})")
    for g in healthy:
        r = results[g]
        assert r.status == "CONVERGED" and r.converged, (
            f"lane {g}: expected CONVERGED, got {r.status}")


def _field(state, name):
    """A VM-state field from either a BatchedVMState or a snapshot dict."""
    if isinstance(state, dict):
        return np.asarray(state[name])
    return np.asarray(getattr(state, name))


def assert_vm_states_equal(st1, st2, *, lane=None,
                           fields=("it", "mem", "queues", "sregs")):
    """Two VM states (or host snapshots of them) are bitwise equal on
    ``fields`` — for one lane's slice when ``lane`` is given, else on
    the full lane axis.  ``mem``/``queues``/``sregs`` carry lanes on
    axis 1, ``it``/``status``/``active`` on axis 0."""
    for f in fields:
        a, b = _field(st1, f), _field(st2, f)
        if lane is not None:
            a, b = (a[:, lane], b[:, lane]) if a.ndim > 1 else \
                   (a[lane], b[lane])
        assert np.array_equal(a, b, equal_nan=True), (
            f"VM state field {f!r} differs"
            + ("" if lane is None else f" on lane {lane}"))
