"""Training substrate: optimizer math, microbatching, trainer, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import init_params
from repro.models.config import ModelConfig
from repro.train import (AdamWConfig, DataConfig, SyntheticLM, Trainer,
                         TrainerConfig, adamw_init, adamw_update,
                         cosine_schedule, make_train_step)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                  dtype="float32", remat=False)


class TestAdamW:
    def test_bf16_state_compression(self):
        params = {"w": jnp.ones((8, 8))}
        opt = AdamWConfig(state_dtype="bfloat16")
        st = adamw_init(params, opt)
        assert st.m["w"].dtype == jnp.bfloat16
        g = {"w": jnp.full((8, 8), 0.1)}
        p2, st2 = adamw_update(g, st, params, opt, jnp.asarray(1e-2))
        assert st2.m["w"].dtype == jnp.bfloat16
        assert p2["w"].dtype == params["w"].dtype
        assert bool(jnp.all(p2["w"] < params["w"]))   # moved downhill

    def test_matches_reference_adam(self):
        """fp32-state AdamW step == hand-computed Adam + decoupled decay."""
        opt = AdamWConfig(state_dtype="float32", weight_decay=0.1,
                          grad_clip=0.0, b1=0.9, b2=0.999, eps=1e-8)
        w0 = np.full((4, 4), 2.0)
        g = np.full((4, 4), 0.5)
        params = {"w": jnp.asarray(w0)}
        st = adamw_init(params, opt)
        lr = 1e-2
        p2, _ = adamw_update({"w": jnp.asarray(g)}, st, params, opt,
                             jnp.asarray(lr))
        m = 0.1 * g / (1 - 0.9)
        v = 0.001 * g * g / (1 - 0.999)
        want = w0 - lr * (m / (np.sqrt(v) + 1e-8) + 0.1 * w0)
        np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)

    def test_grad_clip(self):
        from repro.train.optim import clip_by_global_norm
        g = {"a": jnp.full((10,), 100.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
        norm2 = float(jnp.linalg.norm(clipped["a"]))
        assert norm2 == pytest.approx(1.0, rel=1e-5)

    def test_bias_decay_exempt(self):
        """1-D params (biases, norms) skip weight decay."""
        opt = AdamWConfig(state_dtype="float32", weight_decay=1.0,
                          grad_clip=0.0)
        params = {"b": jnp.ones((8,))}
        st = adamw_init(params, opt)
        zero_g = {"b": jnp.zeros((8,))}
        p2, _ = adamw_update(zero_g, st, params, opt, jnp.asarray(1e-2))
        np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(jnp.asarray(60))) == pytest.approx(0.5, rel=0.05)


class TestMicrobatching:
    def test_microbatch_grads_equal_full_batch(self):
        """k-microbatch accumulation == single-batch step (same update)."""
        params = init_params(CFG, jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab=256, seq_len=32, global_batch=8))
        batch = data.batch_at(0)
        opt = AdamWConfig(lr=1e-2, state_dtype="float32")
        f1 = make_train_step(CFG, opt=opt, microbatches=1, donate=False)
        f4 = make_train_step(CFG, opt=opt, microbatches=4, donate=False)
        p1, _, m1 = f1(params, adamw_init(params, opt), batch,
                       jnp.asarray(0))
        p4, _, m4 = f4(params, adamw_init(params, opt), batch,
                       jnp.asarray(0))
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                                  rel=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)


class TestData:
    def test_deterministic(self):
        d1 = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=4))
        d2 = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=4))
        np.testing.assert_array_equal(d1.batch_at(7)["tokens"],
                                      d2.batch_at(7)["tokens"])
        assert not np.array_equal(d1.batch_at(7)["tokens"],
                                  d1.batch_at(8)["tokens"])

    def test_labels_are_shifted(self):
        d = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=2))
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["labels"][:, :-1],
                                      b["tokens"][:, 1:])

    def test_markov_band(self):
        d = SyntheticLM(DataConfig(vocab=1000, seq_len=64, global_batch=4,
                                   source="markov", band=8))
        t = np.asarray(d.batch_at(0)["tokens"])
        diff = (t[:, 1:] - t[:, :-1]) % 1000
        diff = np.minimum(diff, 1000 - diff)
        assert diff.max() <= 8


class TestTrainer:
    def test_loss_decreases_and_resume_bitwise(self, tmp_path):
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = AdamWConfig(lr=5e-3, state_dtype="float32")
        step = make_train_step(CFG, opt=opt)
        data = SyntheticLM(DataConfig(vocab=256, seq_len=32, global_batch=8))
        tc = TrainerConfig(total_steps=12, ckpt_every=6,
                           ckpt_dir=str(tmp_path), log_every=0)
        tr = Trainer(CFG, data, step, params, adamw_init(params, opt), tc)
        log = tr.run()
        assert log[-1]["loss"] < log[0]["loss"]

        # uninterrupted reference
        params_r = init_params(CFG, jax.random.PRNGKey(0))
        tr_ref = Trainer(CFG, data, step, params_r,
                         adamw_init(params_r, opt),
                         TrainerConfig(total_steps=18, ckpt_every=0,
                                       ckpt_dir=str(tmp_path / "x"),
                                       log_every=0))
        ref_log = tr_ref.run()

        # resume from the step-12 checkpoint and run 6 more
        params2 = init_params(CFG, jax.random.PRNGKey(1))   # junk template
        tr2 = Trainer(CFG, data, step, params2,
                      adamw_init(params2, opt),
                      TrainerConfig(ckpt_dir=str(tmp_path), log_every=0))
        assert tr2.try_resume() and tr2.step == 12
        log2 = tr2.run(steps=6)
        # bitwise-deterministic resume: identical loss trajectory
        for a, b in zip(log2, ref_log[12:]):
            assert a["loss"] == b["loss"], (a, b)
