"""Lane-sharded multi-device solves (ISSUE 10).

The tentpole contract: placing the lane axis ``[G, n]`` on a
``jax.sharding.Mesh`` is **invisible in the bits** — every observable
(per-lane x, iteration count, final ‖r‖², residual trace, structured
exit status) of a sharded solve is bitwise identical to the unsharded
run, for every scheme × layout × engine × chunking, including bags
whose lanes converge, break down, or exhaust ``maxiter`` mid-chunk on
*different* shards.  All lane math is lane-elementwise and the one
cross-lane reduction (the ``any(active)`` sync) is a deterministic
boolean OR, so sharding must cost nothing — these tests pin that down.

Two coverage tiers, honoring the conftest rule that the main session
keeps a single CPU device:

* **in-process** tests build a mesh over all *visible* devices — 1 in
  the default session (the sharded code path, placement, padding and
  cache keys are still fully exercised), 8 in CI's ``distributed``
  lane (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
  selected with ``-m distributed``);
* **subprocess** tests force 8 host devices regardless of the parent
  session, so tier-1 always proves true multi-device bit-identity and
  the mesh-size cache economics (marked ``slow``: they recompile the
  world in a child interpreter).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.batch import jpcg_solve_batched
from repro.core.shard import (lane_mesh, mesh_shards, mesh_signature,
                              pad_lanes)
from repro.serve.solver_engine import SolverEngine, SolverEngineConfig
from repro.sparse import csr_from_coo, random_spd, tridiagonal_spd
from tests._hyp import given, settings, strategies as st
from tests.oracles import assert_results_bit_identical, assert_statuses

pytestmark = pytest.mark.distributed

BK = dict(block_rows=8, col_tile=128)
#: the four faithful schemes (FP64 vector file — exactly reproducible).
SCHEMES = ("fp64", "mixed_v1", "mixed_v2", "mixed_v3")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _singular_J(n):
    """All-ones rank-1 matrix + sum-zero rhs: pAp = 0 on the first tick
    in any float width -> BREAKDOWN_INDEFINITE."""
    i = np.repeat(np.arange(n), n)
    j = np.tile(np.arange(n), n)
    a = csr_from_coo(i, j, np.ones(n * n), (n, n))
    b = np.zeros(n)
    b[0], b[1] = 1.0, -1.0
    return a, b


#: the bag below is solved with maxiter=MAXITER — deliberately NOT a
#: multiple of steps_per_sync=8, so budget exits land mid-chunk.
MAXITER = 11


def _mixed_fate_bag(n, seed):
    """5 lanes whose fates diverge mid-chunk (and, on a real mesh, on
    different shards): converge fast, exhaust maxiter, break down
    indefinite, run long, break down non-finite."""
    sing_a, sing_b = _singular_J(n)
    nan_b = np.ones(n)
    nan_b[0] = np.nan
    probs = [tridiagonal_spd(n, off=-0.1),        # CONVERGED (~4 ticks)
             random_spd(n, cond=1e6, seed=seed + 1),   # MAXITER (1e-30)
             sing_a,                              # BREAKDOWN_INDEFINITE
             random_spd(n, cond=50.0, seed=seed),  # runs long
             tridiagonal_spd(n)]                  # BREAKDOWN_NONFINITE
    bs = [np.ones(n), np.ones(n), sing_b, np.ones(n), nan_b]
    tols = [1e-10, 1e-30, 1e-10, 1e-10, 1e-10]
    return probs, bs, tols


EXPECTED = {1: "MAXITER", 2: "BREAKDOWN_INDEFINITE",
            4: "BREAKDOWN_NONFINITE"}


class TestShardedBitIdentity:
    """∀ scheme × layout × engine × chunking: mesh placement is
    bitwise invisible, mixed lane fates included."""

    @settings(max_examples=8, deadline=None)
    @given(scheme=st.sampled_from(SCHEMES),
           layout=st.sampled_from(["rowell", "sell"]),
           sps=st.sampled_from([1, 8]),
           engine=st.sampled_from(["vm", "phases"]),
           n=st.sampled_from([16, 24]), seed=st.integers(0, 2**16))
    def test_sharded_equals_unsharded_property(self, scheme, layout, sps,
                                               engine, n, seed):
        probs, bs, tols = _mixed_fate_bag(n, seed)
        kw = dict(tol=tols, maxiter=MAXITER, scheme=scheme,
                  layout=layout, engine=engine, steps_per_sync=sps,
                  with_trace=True, **BK)
        ref = jpcg_solve_batched(probs, bs, **kw)
        got = jpcg_solve_batched(probs, bs, mesh=lane_mesh(), **kw)
        assert_statuses(ref, EXPECTED, healthy=(0,), maxiter=100)
        assert_results_bit_identical(got, ref, rr=True, trace=True,
                                     status=True)

    def test_generic_vm_path_sharded(self):
        """The traced-program (specialize=False) VM path shards too."""
        probs, bs, tols = _mixed_fate_bag(16, seed=3)
        kw = dict(tol=tols, maxiter=MAXITER, specialize=False, **BK)
        ref = jpcg_solve_batched(probs, bs, **kw)
        got = jpcg_solve_batched(probs, bs, mesh=lane_mesh(), **kw)
        assert_results_bit_identical(got, ref, rr=True, status=True)

    def test_lane_padding_is_invisible(self):
        """G not divisible by the shard count pads with inert identity
        lanes — the result list and the metrics see only the real G."""
        from repro.core.metrics import reset_solver_metrics, solver_metrics
        probs, bs, tols = _mixed_fate_bag(16, seed=1)
        mesh = lane_mesh()
        assert pad_lanes(len(probs), mesh) % mesh_shards(mesh) == 0
        reset_solver_metrics()
        try:
            res = jpcg_solve_batched(probs, bs, tol=tols,
                                     maxiter=MAXITER, mesh=mesh, **BK)
            assert len(res) == len(probs)
            m = solver_metrics().snapshot()
            assert m["lanes"] == len(probs)
            assert sum(m["exit_status"].values()) == len(probs)
        finally:
            reset_solver_metrics()

    def test_sharded_engine_matches_unsharded(self):
        """A sharded SolverEngine serving mixed-fate requests harvests
        bit-identical results and the exact same exit histogram."""
        def drive(mesh):
            eng = SolverEngine(SolverEngineConfig(
                batch_slots=8, chunk_iters=8, mesh=mesh, **BK))
            probs, bs, tols = _mixed_fate_bag(16, seed=5)
            rids = [eng.submit(a, b, tol=t, maxiter=MAXITER)
                    for a, b, t in zip(probs, bs, tols)]
            eng.run_to_completion()
            return [eng.results[r] for r in rids], eng.metrics()

        ref, m_ref = drive(None)
        got, m_got = drive(lane_mesh())
        assert_results_bit_identical(got, ref, status=True)
        assert m_got["exit_status"] == m_ref["exit_status"]
        assert m_got["admits"] == m_ref["admits"] == 5
        assert m_got["harvests"] == 5

    def test_mesh_signature_splits_executable_key(self):
        """Cache economics, tier-1 face: unsharded and every mesh size
        produce distinct keys — a 1-device mesh is NOT the unsharded
        executable (placement differs), and sizes never collide."""
        from repro.core.compile import executable_key
        base = dict(backend="xla", scheme="mixed_v3", bucket=(256, 8),
                    layout="rowell", index_bytes=2, steps_per_sync=8,
                    donate=False, interpret=False)
        sigs = [None, (("lanes", 1),), (("lanes", 2),), (("lanes", 8),)]
        keys = {executable_key("stepper", mesh=s, **base) for s in sigs}
        assert len(keys) == len(sigs)
        assert mesh_signature(None) is None
        assert mesh_signature(lane_mesh()) == \
            (("lanes", mesh_shards(lane_mesh())),)


class TestShardedSoak:
    """Satellite: a seeded ~200-tick randomized soak against a sharded
    engine — admissions, steps, harvests, compactions and bucket growth
    interleave; every request terminates classified and the metrics
    balance exactly."""

    KINDS = ("easy", "hard", "budget", "singular", "nonfinite")
    WANT = {"easy": "CONVERGED", "hard": "CONVERGED",
            "budget": "MAXITER", "singular": "BREAKDOWN_INDEFINITE",
            "nonfinite": "BREAKDOWN_NONFINITE"}

    def _submit(self, eng, rng, k):
        kind = self.KINDS[int(rng.integers(0, len(self.KINDS)))]
        # sizes straddle a bucket edge (16 vs 24->32) so admissions
        # keep forcing mid-flight bucket growth after compactions
        n = int(rng.choice([16, 24]))
        if kind == "easy":
            rid = eng.submit(tridiagonal_spd(n, off=-0.1), np.ones(n),
                             tol=1e-10, maxiter=500)
        elif kind == "hard":
            rid = eng.submit(random_spd(n, cond=100.0, seed=k),
                             np.ones(n), tol=1e-10, maxiter=500)
        elif kind == "budget":
            rid = eng.submit(tridiagonal_spd(n), np.ones(n),
                             tol=1e-30, maxiter=3)
        elif kind == "singular":
            a, b = _singular_J(n)
            rid = eng.submit(a, b, tol=1e-10, maxiter=500)
        else:
            a = tridiagonal_spd(n)
            b = np.ones(n)
            b[0] = np.nan
            rid = eng.submit(a, b, tol=1e-10, maxiter=500)
        return rid, kind

    @pytest.mark.slow
    def test_soak_200_ticks(self):
        rng = np.random.default_rng(20260808)
        eng = SolverEngine(SolverEngineConfig(
            batch_slots=8, chunk_iters=4, compact_fraction=0.75,
            mesh=lane_mesh(), **BK))
        kinds = {}
        for tick in range(200):
            if rng.random() < 0.4 and eng.free_slots() > 0:
                rid, kind = self._submit(eng, rng, tick)
                kinds[rid] = kind
            eng.step()
        eng.run_to_completion()

        assert kinds, "soak admitted nothing — broken driver"
        assert set(eng.results) == set(kinds)
        hist = {}
        for rid, kind in kinds.items():
            res = eng.results[rid]
            want = self.WANT[kind]
            assert res.status == want, (kind, res.status)
            assert res.converged == (want == "CONVERGED")
            hist[want] = hist.get(want, 0) + 1

        m = eng.metrics()
        n_req = len(kinds)
        assert m["admits"] == n_req
        assert m["harvests"] == n_req
        assert m.get("escalations", 0) == 0
        assert m["exit_status"] == hist
        assert sum(m["exit_status"].values()) == n_req
        for p in m["pools"].values():
            assert p["occupied"] == 0 and p["active"] == 0
            assert p["shards"] == mesh_shards(lane_mesh())


# --------------------------------------------------- 8-device subprocess
def _run(body: str, devices: int = 8, prelude: str = "") -> dict:
    """Run a snippet under N forced host devices; it must print JSON.
    (Subprocess per the conftest rule: the main session stays at one
    device; see tests/test_distributed.py for the same idiom.)
    ``prelude`` is prepended already-dedented (module-level helpers)."""
    snippet = prelude + textwrap.dedent(body)
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        import jax.numpy as jnp
        {textwrap.indent(snippet, '        ').strip()}
        """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


_BAG_SRC = '''
from repro.sparse import csr_from_coo, random_spd, tridiagonal_spd

def mixed_fate_bag(n, seed):
    i = np.repeat(np.arange(n), n); j = np.tile(np.arange(n), n)
    sing_a = csr_from_coo(i, j, np.ones(n * n), (n, n))
    sing_b = np.zeros(n); sing_b[0], sing_b[1] = 1.0, -1.0
    nan_b = np.ones(n); nan_b[0] = np.nan
    probs = [tridiagonal_spd(n, off=-0.1),
             random_spd(n, cond=1e6, seed=seed + 1), sing_a,
             random_spd(n, cond=50.0, seed=seed), tridiagonal_spd(n)]
    bs = [np.ones(n), np.ones(n), sing_b, np.ones(n), nan_b]
    return probs, bs, [1e-10, 1e-30, 1e-10, 1e-10, 1e-10]

def _eq(a, b):
    # NaN-tolerant bitwise compare; engine results carry
    # residual_trace=None, where equal_nan would choke on isnan
    a, b = np.asarray(a), np.asarray(b)
    nan_ok = a.dtype.kind == "f" and b.dtype.kind == "f"
    return np.array_equal(a, b, equal_nan=nan_ok)

def lanes_equal(r, o):
    return (r.iterations == o.iterations and r.status == o.status
            and _eq(r.rr, o.rr) and _eq(r.x, o.x)
            and _eq(r.residual_trace, o.residual_trace))
'''


@pytest.mark.slow                 # subprocess + 8 host devices
class TestEightDevices:
    def test_bit_identity_8dev(self):
        """True 8-device run: G=5 pads to 8, one lane per shard, every
        observable bit-identical to the unsharded solve across scheme ×
        layout × chunking × engine."""
        out = _run("""
            from repro.core.batch import jpcg_solve_batched
            from repro.core.shard import lane_mesh
            mesh = lane_mesh()
            probs, bs, tols = mixed_fate_bag(16, seed=7)
            detail = []
            for scheme in ("fp64", "mixed_v3"):
                for layout in ("rowell", "sell"):
                    for engine, sps in (("vm", 1), ("vm", 8),
                                        ("phases", 8)):
                        kw = dict(tol=tols, maxiter=11, scheme=scheme,
                                  layout=layout, engine=engine,
                                  steps_per_sync=sps, with_trace=True,
                                  block_rows=8, col_tile=128)
                        ref = jpcg_solve_batched(probs, bs, **kw)
                        got = jpcg_solve_batched(probs, bs, mesh=mesh,
                                                 **kw)
                        same = len(got) == len(ref) and all(
                            lanes_equal(r, o) for r, o in zip(got, ref))
                        detail.append([scheme, layout, engine, sps,
                                       bool(same)])
            print(json.dumps({"devices": jax.device_count(),
                              "detail": detail}))
        """, prelude=_BAG_SRC)
        assert out["devices"] == 8
        bad = [d for d in out["detail"] if not d[-1]]
        assert not bad, f"sharded run not bit-identical: {bad}"

    def test_engine_8dev_matches_unsharded(self):
        """Sharded SolverEngine on 8 real devices: bit-identical
        harvests, identical exit histogram, device-local compaction."""
        out = _run("""
            from repro.serve.solver_engine import (SolverEngine,
                                                   SolverEngineConfig)
            from repro.core.shard import lane_mesh

            def drive(mesh):
                eng = SolverEngine(SolverEngineConfig(
                    batch_slots=8, chunk_iters=8, mesh=mesh,
                    block_rows=8, col_tile=128))
                probs, bs, tols = mixed_fate_bag(16, seed=5)
                rids = [eng.submit(a, b, tol=t, maxiter=11)
                        for a, b, t in zip(probs, bs, tols)]
                eng.run_to_completion()
                return ([eng.results[r] for r in rids], eng.metrics())

            ref, m_ref = drive(None)
            got, m_got = drive(lane_mesh())
            same = all(lanes_equal(r, o) for r, o in zip(got, ref))
            shards = [p["shards"] for p in m_got["pools"].values()]
            print(json.dumps({"devices": jax.device_count(),
                              "same": bool(same),
                              "hist_equal": m_got["exit_status"] ==
                                            m_ref["exit_status"],
                              "shards": shards}))
        """, prelude=_BAG_SRC)
        assert out["devices"] == 8
        assert out["same"] and out["hist_equal"]
        assert out["shards"] == [8]

    def test_cache_economics_mesh_sizes(self):
        """Satellite: mesh sizes {1, 2, 8} are three distinct
        executables — one compile each (a repeat is a pure cache hit,
        no retrace), and none collide with each other."""
        out = _run("""
            from repro.core.batch import (batch_cache_clear,
                                          batch_cache_info,
                                          jpcg_solve_batched)
            from repro.core.shard import lane_mesh
            from repro.core.vm import vm_executable_stats
            from repro.sparse import tridiagonal_spd
            devs = jax.devices()
            # G=8: divisible by every mesh size, so the lane bucket is
            # identical everywhere — only the mesh field distinguishes
            probs = [tridiagonal_spd(16 + 2 * g) for g in range(8)]
            batch_cache_clear()
            seq = []
            for d in (1, 2, 8):
                mesh = lane_mesh(devs[:d])
                for _ in range(2):
                    jpcg_solve_batched(probs, tol=1e-10, maxiter=20,
                                       mesh=mesh, block_rows=8,
                                       col_tile=128)
                info = batch_cache_info()
                seq.append([d, info["entries"], info["misses"],
                            info["hits"], vm_executable_stats()["traces"]])
            print(json.dumps({"seq": seq}))
        """)
        entries = [row[1] for row in out["seq"]]
        misses = [row[2] for row in out["seq"]]
        hits = [row[3] for row in out["seq"]]
        traces = [row[4] for row in out["seq"]]
        # one new entry + one miss per mesh size; the repeat is a hit
        assert entries == [1, 2, 3]
        assert misses == [1, 2, 3]
        assert hits == [1, 2, 3]
        # exactly one jit trace per mesh size — the repeat retraced
        # nothing (no silent double compile behind the key)
        assert traces[0] >= 1
        assert traces[1] == traces[0] + 1
        assert traces[2] == traces[1] + 1
