"""Distributed layer tests — multi-device cases run in subprocesses so the
main pytest session keeps its single CPU device (per the assignment: no
global --xla_force_host_platform_device_count)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, devices: int = 8) -> dict:
    """Run a snippet under N forced host devices; it must print JSON."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        import jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


class TestShardingRules:
    def test_param_specs_shapes(self):
        """Rules give TP on output features, FSDP on inputs, EP on
        experts; uneven dims fall back to replication."""
        from repro.distributed.sharding import param_specs
        from repro.launch.mesh import make_mesh  # noqa: F401

        class Leaf:
            def __init__(self, shape):
                self.shape = shape

        tree = {
            "embed": {"e": Leaf((152064, 5120))},
            "layers": {
                "attn": {"wq": {"w": Leaf((64, 5120, 5120))},
                         "wo": {"w": Leaf((64, 5120, 5120))}},
                "moe": {"wi": Leaf((24, 32, 1024, 512)),
                        "wo": Leaf((24, 32, 512, 1024)),
                        "router": {"w": Leaf((24, 1024, 32))}},
                "ln1": {"g": Leaf((64, 5120))},
            },
        }
        specs = param_specs(tree)
        assert specs["embed"]["e"] == P("model", None)
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
        assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", "data")
        assert specs["layers"]["moe"]["wi"] == P(None, "model", "data", None)
        assert specs["layers"]["ln1"]["g"] == P(None, None)

    def test_divisibility_fit(self):
        from repro.distributed.sharding import param_specs
        import numpy as np
        if jax.device_count() != 1:
            pytest.skip("needs the default single-device session")
        mesh = jax.make_mesh((1, 1), ("data", "model"))

        class Leaf:
            def __init__(self, shape):
                self.shape = shape
        # 51865 not divisible by 1? always divisible — use a fake mesh via
        # subprocess below for the real check; here just shape sanity.
        specs = param_specs({"embed": {"e": Leaf((51865, 512))}}, mesh)
        assert specs["embed"]["e"] is not None


@pytest.mark.slow                 # subprocess + 8 host devices
class TestDistributedCG:
    @pytest.mark.parametrize("method", ["vsr", "pipelined"])
    def test_solves_poisson_8dev(self, method):
        out = _run(f"""
            from repro.sparse import poisson_2d, csr_to_dense
            from repro.distributed import make_dist_solver
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            A = poisson_2d(40)
            solver = make_dist_solver(A, mesh, scheme="mixed_v3",
                                      method="{method}", tol=1e-12,
                                      maxiter=4000, block_rows=8,
                                      col_tile=128)
            b = np.ones(1600)
            x, it, rr = solver.solve(jnp.asarray(b), jnp.zeros(1600),
                                     jnp.asarray(A.diagonal()))
            resid = float(np.linalg.norm(csr_to_dense(A) @ np.asarray(x) - b))
            print(json.dumps({{"iters": int(it), "rr": float(rr),
                               "resid": resid}}))
        """)
        assert out["rr"] <= 1e-12
        assert out["resid"] < 1e-4

    def test_dist_matches_single_device(self):
        out = _run("""
            from repro.sparse import poisson_2d
            from repro.distributed import make_dist_solver
            from repro.core.cg import jpcg_solve
            mesh = jax.make_mesh((8,), ("rows",))
            A = poisson_2d(32)
            solver = make_dist_solver(A, mesh, scheme="mixed_v3",
                                      method="vsr", tol=1e-12,
                                      maxiter=3000, block_rows=8,
                                      col_tile=128)
            x, it, rr = solver.solve(jnp.ones(1024), jnp.zeros(1024),
                                     jnp.asarray(A.diagonal()))
            ref = jpcg_solve(A, tol=1e-12, maxiter=3000, block_rows=8,
                             col_tile=128)
            err = float(np.abs(np.asarray(x) - np.asarray(ref.x)).max())
            print(json.dumps({"iters": int(it), "ref": ref.iterations,
                              "err": err}))
        """)
        assert out["iters"] == out["ref"]
        assert out["err"] < 1e-9

    def test_pipelined_single_reduction(self):
        """Count all-reduces in the compiled loop body: pipelined has ONE
        fused psum per iteration, vsr has TWO."""
        out = _run("""
            from repro.sparse import poisson_2d
            from repro.distributed import make_dist_solver
            from repro.roofline.hlo_cost import _parse_computations
            mesh = jax.make_mesh((8,), ("rows",))
            A = poisson_2d(16)

            def count(method):
                import repro.distributed.cg_dist as cgd
                from repro.sparse.partition import partition_rows
                part = partition_rows(A, 8, block_rows=8, col_tile=128)
                s = cgd.make_dist_solver(A, mesh, scheme="mixed_v3",
                                         method=method, tol=1e-12,
                                         maxiter=100, block_rows=8,
                                         col_tile=128, part=part)
                lowered = jax.jit(s.solve.__wrapped__).lower(
                    jnp.ones(256), jnp.zeros(256),
                    jnp.asarray(A.diagonal()))
                txt = lowered.compile().as_text()
                # all-reduces inside the main while body only
                comps = _parse_computations(txt)
                body = max((c for n, c in comps.items()
                            if n.startswith("region") or "body" in n),
                           key=lambda c: sum(1 for i in c), default=[])
                import re
                n_ar = 0
                for name, comp in comps.items():
                    if "__entry__" == name: continue
                    for ins in comp:
                        if ins.opcode.startswith("all-reduce"):
                            n_ar += 1
                return n_ar

            print(json.dumps({"vsr": count("vsr"),
                              "pipe": count("pipelined")}))
        """)
        assert out["pipe"] < out["vsr"]


@pytest.mark.slow                 # subprocess + 8 host devices
class TestHaloExchange:
    def test_halo_equals_allgather(self):
        """Stencil fast path: neighbor-permute halo SpMV solves
        identically to the all-gather SpMV, with far less wire traffic."""
        out = _run("""
            from repro.sparse import poisson_2d, csr_to_dense
            from repro.distributed import make_dist_solver
            from repro.roofline.hlo_cost import walk_hlo
            mesh = jax.make_mesh((8,), ("rows",))
            A = poisson_2d(64)
            d = csr_to_dense(A); b = np.ones(4096)
            res = {}
            for comm in ("allgather", "halo"):
                s = make_dist_solver(A, mesh, scheme="mixed_v3",
                                     method="vsr", tol=1e-12, maxiter=3000,
                                     block_rows=8, col_tile=64, comm=comm)
                x, it, rr = s.solve(jnp.asarray(b), jnp.zeros(4096),
                                    jnp.asarray(A.diagonal()))
                lowered = jax.jit(s.solve.__wrapped__).lower(
                    jnp.ones(4096), jnp.zeros(4096),
                    jnp.asarray(A.diagonal()))
                w = walk_hlo(lowered.compile().as_text(), default_group=8)
                res[comm] = {"iters": int(it),
                             "resid": float(np.linalg.norm(
                                 d @ np.asarray(x) - b)),
                             "wire": w.wire_bytes}
            print(json.dumps(res))
        """)
        assert out["halo"]["iters"] == out["allgather"]["iters"]
        assert out["halo"]["resid"] < 1e-4
        # the x-window exchange shrinks dramatically; dots still psum
        assert out["halo"]["wire"] < 0.5 * out["allgather"]["wire"]

    def test_auto_selects_halo_for_stencil(self):
        out = _run("""
            from repro.sparse import poisson_2d
            from repro.sparse.partition import partition_rows
            part = partition_rows(poisson_2d(64), 8, block_rows=8,
                                  col_tile=64)
            print(json.dumps({"supports": bool(part.supports_halo),
                              "halo": int(part.halo_width),
                              "pad": int(part.halo_pad)}))
        """, devices=1)
        assert out["supports"] and out["halo"] == 64


@pytest.mark.slow                 # subprocess + 8 host devices
class TestElasticRemesh:
    def test_save_mesh_a_restore_mesh_b(self, tmp_path):
        out = _run(f"""
            from repro.models import init_params
            from repro.models.config import ModelConfig
            from repro.train import checkpoint as ckpt
            from repro.train.fault import elastic_restore
            from repro.distributed.sharding import named_shardings, param_specs

            cfg = ModelConfig(name="t", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                              vocab=256, head_dim=16, dtype="float32",
                              remat=False)
            params = init_params(cfg, jax.random.PRNGKey(0))
            mesh_a = jax.make_mesh((4, 2), ("data", "model"))
            sh_a = named_shardings(param_specs(params, mesh_a), mesh_a)
            params_a = jax.tree_util.tree_map(jax.device_put, params, sh_a)
            ckpt.save("{tmp_path}", 1, params_a)

            mesh_b = jax.make_mesh((2, 4), ("data", "model"))
            restored, _ = elastic_restore("{tmp_path}", params, mesh_b)
            ok = all(bool(jnp.allclose(a.astype(jnp.float32),
                                       b.astype(jnp.float32)))
                     for a, b in zip(jax.tree_util.tree_leaves(params),
                                     jax.tree_util.tree_leaves(restored)))
            some = jax.tree_util.tree_leaves(restored)[3]
            print(json.dumps({{"ok": ok,
                               "resharded": str(some.sharding.mesh.shape)}}))
        """)
        assert out["ok"]
        assert "2" in out["resharded"] and "4" in out["resharded"]


@pytest.mark.slow                 # subprocess + 8 host devices
class TestMeshTrainStep:
    def test_sharded_train_step_runs(self):
        """make_train_step(mesh=...) produces a runnable sharded step."""
        out = _run("""
            from repro.models import init_params
            from repro.models.config import ModelConfig
            from repro.train import (AdamWConfig, adamw_init,
                                     make_train_step, SyntheticLM,
                                     DataConfig)
            from repro.distributed.hints import sharding_hints

            cfg = ModelConfig(name="t", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                              vocab=256, head_dim=16, dtype="float32",
                              remat=False)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            params = init_params(cfg, jax.random.PRNGKey(0))
            pshape = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
            opt = AdamWConfig(lr=1e-2, state_dtype="float32")
            jit_for = make_train_step(cfg, mesh, opt=opt,
                                      params_shape=pshape, donate=False)
            data = SyntheticLM(DataConfig(vocab=256, seq_len=32,
                                          global_batch=8))
            batch = data.batch_at(0)
            bshape = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
            with sharding_hints(mesh):
                step = jit_for(bshape)
                p, o, m = step(params, adamw_init(params, opt), batch,
                               jnp.asarray(0, jnp.int32))
                p, o, m2 = step(p, o, data.batch_at(1),
                                jnp.asarray(1, jnp.int32))
            print(json.dumps({"l0": float(m["loss"]),
                              "l1": float(m2["loss"])}))
        """)
        assert out["l0"] > 0 and out["l1"] > 0
