"""Checkpoint layer: atomicity, verification, versioning, bf16."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t, {"note": "hi"})
    out, meta = ckpt.restore(str(tmp_path), t)
    assert meta == {"note": "hi"}
    for a, b in zip(jnp.asarray(t["a"]).ravel(),
                    jnp.asarray(out["a"]).ravel()):
        assert a == b
    assert out["nested"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"],
                                             np.float32), 1.0)


def test_versioning_and_latest(tmp_path):
    t = _tree()
    for s in (1, 5, 3):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.list_steps(str(tmp_path)) == [1, 3, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5
    out, _ = ckpt.restore(str(tmp_path), t, step=3)
    assert out is not None


def test_torn_write_is_invisible(tmp_path):
    """A crash mid-write leaves only *.tmp — restore never sees it."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a torn write at step 2
    torn = tmp_path / "step_000000002.tmp"
    os.makedirs(torn)
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1
    out, _ = ckpt.restore(str(tmp_path), t)     # restores step 1, no error


def test_corruption_detected(tmp_path):
    t = _tree()
    path = ckpt.save(str(tmp_path), 7, t)
    payload = os.path.join(path, "arrays.npz")
    data = bytearray(open(payload, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(payload, "wb").write(bytes(data))
    with pytest.raises(IOError, match="hash mismatch"):
        ckpt.restore(str(tmp_path), t)


def test_shape_mismatch_detected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = dict(t)
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_missing_leaf_detected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bigger = dict(t)
    bigger["extra"] = jnp.zeros(3)
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), bigger)


def test_idempotent_resave(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 2, t)
    ckpt.save(str(tmp_path), 2, t)              # no error, one entry
    assert ckpt.list_steps(str(tmp_path)) == [2]


def test_manifest_contents(tmp_path):
    t = _tree()
    path = ckpt.save(str(tmp_path), 4, t, {"cursor": {"step": 4}})
    m = json.load(open(os.path.join(path, "manifest.json")))
    assert m["step"] == 4
    assert m["metadata"]["cursor"]["step"] == 4
    assert m["leaves"]["nested/b"]["dtype"] == "bfloat16"
    assert len(m["sha256"]) == 64
