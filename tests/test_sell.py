"""Sliced-ELL (SELL-C-σ) layout — ISSUE 8.

The layout contract under test: row-ELL, sliced-ELL (XLA and Pallas)
and a plain-numpy reference produce **bit-identical** SpMV results for
every precision scheme — the per-row slot order and the suffix-stable
halving-tree bracketing are layout-invariant, so the solver trajectory
cannot depend on which packing a bag happens to pick.  Plus the
satellite guarantees: self-gathering padding (no cross-row poisoning),
int16/int32 index-width selection at the 2^15 boundary, the
padding-ratio auto heuristic on both front doors, and executable-cache
splits on layout/index width.
"""
import numpy as np
import pytest

from repro.core.batch import (batched_matvec_rowell, batched_matvec_sell,
                              jpcg_solve_batched, tree_sum)
from repro.core.cg import jpcg_solve
from repro.core.precision import get_scheme
from repro.sparse import (diag_dominant_spd, poisson_2d, powerlaw_spd,
                          tridiagonal_spd)
from repro.sparse.stacking import (choose_layout, csr_rowell,
                                   index_bytes_for, index_dtype,
                                   rowell_padding_ratio, stack_rowell,
                                   stack_sell)
from repro.serve.solver_engine import SolverEngine, SolverEngineConfig
from tests._hyp import given, settings, strategies as st
from tests.oracles import assert_results_bit_identical

BK = dict(block_rows=8, col_tile=128)

#: The four schemes the property test sweeps: both faithful-tier mixes
#: that differ in accumulate dtype, plus the fp64 baseline and the
#: TPU-tier headline (bf16 values, fp32 gather/accumulate).
SCHEMES4 = ("fp64", "mixed_v2", "mixed_v3", "tpu_v3")


def _reference_spmv(csrs, xs, scheme):
    """Plain-numpy oracle: per lane at its own *unbucketed* row width,
    gather + correctly-rounded products + the same halving tree.  Any
    padded width ≥ the row's nnz folds to identical bits (suffix-stable
    bracketing), which is exactly what makes this a valid oracle for
    both row-ELL (global W) and sliced-ELL (per-slice w)."""
    sch = get_scheme(scheme)
    mdt = np.dtype(sch.matrix_dtype)
    idt = np.dtype(sch.spmv_in_dtype)
    acc = np.dtype(sch.spmv_acc_dtype)
    outs = []
    for a, x in zip(csrs, xs):
        cols, vals = csr_rowell(a)
        v = vals.astype(mdt).astype(acc)
        g = x.astype(idt)[cols].astype(acc)
        # numpy's v*g is correctly rounded at acc — the same bits
        # rounded_products pins down on the jax side
        y = tree_sum(v * g, axis=1).astype(np.dtype(sch.vector_dtype))
        outs.append(y)
    return outs


def _stacked_x(csrs, xs, n_pad, scheme):
    sch = get_scheme(scheme)
    xp = np.zeros((len(csrs), n_pad), np.dtype(sch.vector_dtype))
    for g, x in enumerate(xs):
        xp[g, : x.shape[0]] = x
    return xp


def _lane_equal(got, ref, csrs):
    for g, (a, r) in enumerate(zip(csrs, ref)):
        n = a.shape[0]
        assert np.array_equal(np.asarray(got)[g, :n], np.asarray(r)[:n]), \
            f"lane {g} differs from the reference"


class TestLayoutBitIdentity:
    """Property: rowell ≡ sell ≡ numpy CSR reference, bitwise, for every
    scheme × backend, including power-law (skewed) row distributions."""

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(8, 160), alpha=st.floats(1.8, 2.6),
           seed=st.integers(0, 9), scheme=st.sampled_from(SCHEMES4),
           skewed=st.booleans(), pallas=st.booleans())
    def test_spmv_layouts_bitwise_equal(self, n, alpha, seed, scheme,
                                        skewed, pallas):
        import jax.numpy as jnp
        if skewed:
            lanes = [powerlaw_spd(n, alpha=alpha, seed=seed),
                     powerlaw_spd(max(5, n // 2), alpha=alpha,
                                  seed=seed + 1)]
        else:
            lanes = [diag_dominant_spd(n, nnz_per_row=min(7, n - 1),
                                       dominance=1.2, seed=seed),
                     tridiagonal_spd(max(5, n // 2))]
        sch = get_scheme(scheme)
        rng = np.random.default_rng(seed)
        xs = [rng.standard_normal(a.shape[0]) for a in lanes]
        ref = _reference_spmv(lanes, xs, scheme)

        st_r = stack_rowell(lanes, scheme=sch)
        xp = _stacked_x(lanes, xs, st_r.padded_rows, scheme)
        y_r = batched_matvec_rowell(jnp.asarray(st_r.cols),
                                    jnp.asarray(st_r.vals),
                                    jnp.asarray(xp), scheme=sch)
        _lane_equal(y_r, ref, lanes)

        st_s = stack_sell(lanes, scheme=sch)
        y_s = batched_matvec_sell(jnp.asarray(st_s.cols),
                                  jnp.asarray(st_s.vals),
                                  jnp.asarray(st_s.iperm),
                                  jnp.asarray(xp), groups=st_s.groups,
                                  scheme=sch)
        _lane_equal(y_s, ref, lanes)
        assert np.array_equal(np.asarray(y_r), np.asarray(y_s)), \
            "row-ELL and sliced-ELL disagree bitwise"

        if pallas:
            from repro.kernels.spmv import spmv_pallas_sell
            y_sorted = spmv_pallas_sell(jnp.asarray(st_s.cols),
                                        jnp.asarray(st_s.vals),
                                        jnp.asarray(xp),
                                        groups=st_s.groups, scheme=sch,
                                        interpret=True)
            y_p = jnp.take_along_axis(y_sorted, jnp.asarray(st_s.iperm),
                                      axis=1).astype(sch.vector_dtype)
            assert np.array_equal(np.asarray(y_p), np.asarray(y_s)), \
                "Pallas sliced-ELL disagrees with the XLA path"


class TestIndexWidth:
    """int16 under the 2^15 bucketed-row boundary, int32 beyond — and
    the packing stays bit-identical across the switch."""

    def test_boundary_dtypes(self):
        assert index_dtype(32767) == np.dtype(np.int16)
        assert index_dtype(32768) == np.dtype(np.int32)
        assert index_bytes_for(16384) == 2       # bucket edge itself
        assert index_bytes_for(16385) == 4       # buckets to 32768
        assert index_bytes_for(40000) == 4

    @pytest.mark.parametrize("n,width", [(1000, 2), (33000, 4)])
    def test_packed_index_width_and_identity(self, n, width):
        import jax.numpy as jnp
        a = tridiagonal_spd(n)
        sch = get_scheme("mixed_v3")
        st_r = stack_rowell([a], scheme=sch)
        st_s = stack_sell([a], scheme=sch)
        assert st_r.index_bytes == st_s.index_bytes == width
        assert st_r.cols.dtype == st_s.cols.dtype == index_dtype(
            st_r.padded_rows)
        x = np.linspace(-1.0, 1.0, a.shape[0])
        ref = _reference_spmv([a], [x], "mixed_v3")
        xp = _stacked_x([a], [x], st_r.padded_rows, "mixed_v3")
        y_r = batched_matvec_rowell(jnp.asarray(st_r.cols),
                                    jnp.asarray(st_r.vals),
                                    jnp.asarray(xp), scheme=sch)
        y_s = batched_matvec_sell(jnp.asarray(st_s.cols),
                                  jnp.asarray(st_s.vals),
                                  jnp.asarray(st_s.iperm),
                                  jnp.asarray(xp), groups=st_s.groups,
                                  scheme=sch)
        _lane_equal(y_r, ref, [a])
        assert np.array_equal(np.asarray(y_r), np.asarray(y_s))


class TestPaddingSelfGather:
    """Satellite 1 regression: padded slots must gather the row's OWN x
    entry (×0), never ``x[0]`` — a non-finite value in x[0] used to
    poison every short row's result through its padding (0·inf = nan)."""

    @pytest.mark.parametrize("stack", [stack_rowell, stack_sell])
    def test_nonfinite_x0_cannot_poison_short_rows(self, stack):
        import jax.numpy as jnp
        a = powerlaw_spd(64, alpha=2.1, seed=3)   # skew: many short rows
        sch = get_scheme("fp64")
        stk = stack([a], scheme=sch)
        x = np.ones(stk.padded_rows)
        x[0] = np.inf
        if stack is stack_rowell:
            y = batched_matvec_rowell(jnp.asarray(stk.cols),
                                      jnp.asarray(stk.vals),
                                      jnp.asarray(x[None]), scheme=sch)
        else:
            y = batched_matvec_sell(jnp.asarray(stk.cols),
                                    jnp.asarray(stk.vals),
                                    jnp.asarray(stk.iperm),
                                    jnp.asarray(x[None]), groups=stk.groups,
                                    scheme=sch)
        y = np.asarray(y)[0]
        # rows with a structural entry in column 0 legitimately see inf;
        # every OTHER row must stay finite
        touches_0 = {int(r) for r in range(a.shape[0])
                     for j in a.indices[a.indptr[r]:a.indptr[r + 1]]
                     if j == 0}
        clean = [r for r in range(a.shape[0]) if r not in touches_0]
        assert clean, "test matrix degenerated: every row touches col 0"
        assert np.all(np.isfinite(y[clean])), \
            "padding gathered a foreign x entry (self-gather regression)"

    def test_padded_slots_self_gather_by_construction(self):
        a = tridiagonal_spd(10)                   # rows 0/9 are short
        stk = stack_rowell([a], scheme=get_scheme("fp64"))
        own = np.arange(stk.padded_rows)
        pad = np.asarray(stk.vals[0]) == 0.0      # [W, n_pad] pad mask
        cols = np.asarray(stk.cols[0], np.int64)
        assert np.all(cols[pad] == np.broadcast_to(own, cols.shape)[pad])


class TestFrontDoorWiring:
    """layout= override + auto heuristic on both front doors, and the
    executable-cache key splitting on the new fields."""

    def test_heuristic_threshold(self):
        skew = [powerlaw_spd(96, alpha=2.1, seed=0)]
        flat = [tridiagonal_spd(96)]
        assert rowell_padding_ratio(skew) > 2.0 > rowell_padding_ratio(flat)
        assert choose_layout(skew) == "sell"
        assert choose_layout(flat) == "rowell"
        assert choose_layout(flat, default="ellpack") == "ellpack"

    def test_batched_layout_override_and_auto(self):
        skew = [powerlaw_spd(96, alpha=2.1, seed=0),
                powerlaw_spd(80, alpha=2.2, seed=1)]
        assert choose_layout(skew) == "sell"
        kw = dict(tol=1e-10, maxiter=300, **BK)
        oracle = jpcg_solve_batched(skew, engine="phases", layout="sell",
                                    **kw)
        for lay in ("rowell", "sell", "auto"):
            got = jpcg_solve_batched(skew, layout=lay, **kw)
            assert_results_bit_identical(got, oracle)

    def test_executable_key_splits_on_layout_and_index_width(self):
        from repro.core.compile import executable_key
        base = dict(backend="xla", scheme="mixed_v3", bucket=(256, 8),
                    steps_per_sync=8, donate=False, interpret=False)
        keys = {executable_key("stepper", layout=lay, index_bytes=ib,
                               **base)
                for lay in ("rowell", "sell") for ib in (2, 4)}
        assert len(keys) == 4

    def test_engine_auto_layout_resolution(self):
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=8,
                                              **BK))
        eng.submit(powerlaw_spd(128, alpha=2.1, seed=2))
        assert eng._pool(None, None).layout == "sell"
        eng.run_to_completion()
        eng2 = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=8,
                                               **BK))
        eng2.submit(tridiagonal_spd(128))
        assert eng2._pool(None, None).layout == "rowell"
        eng2.run_to_completion()

    def test_engine_sell_solve_and_growth(self):
        """A sell pool admits, grows its bucket mid-flight (slice widths
        merge monotonically), harvests — every lane matches the
        single-system solver."""
        eng = SolverEngine(SolverEngineConfig(batch_slots=4, chunk_iters=32,
                                              layout="sell", **BK))
        probs = {0: powerlaw_spd(200, alpha=2.1, seed=1),
                 1: poisson_2d(12)}
        ids = {k: eng.submit(a) for k, a in probs.items()}
        eng.step()
        probs[2] = powerlaw_spd(500, alpha=2.2, seed=2)   # bucket grows
        ids[2] = eng.submit(probs[2])
        eng.run_to_completion()
        for k, a in probs.items():
            ref = jpcg_solve(a, tol=1e-12, maxiter=20_000, **BK)
            got = eng.results[ids[k]]
            assert got.converged
            assert abs(got.iterations - ref.iterations) <= 2
            np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                                       rtol=1e-6, atol=1e-8)


class TestSolverParity:
    """Solver-level acceptance: on a skewed bag the sell VM path is
    bit-identical to the phases oracle for scheme × backend × chunking."""

    SKEW = None

    @classmethod
    def _bag(cls):
        if cls.SKEW is None:
            cls.SKEW = [powerlaw_spd(128, alpha=2.1, seed=4),
                        powerlaw_spd(96, alpha=2.3, seed=5)]
        return cls.SKEW

    @pytest.mark.parametrize("sps", [1, 8])
    @pytest.mark.parametrize("scheme", ["fp64", "mixed_v3", "tpu_v3"])
    def test_sell_vm_matches_phases(self, scheme, sps):
        bag = self._bag()
        kw = dict(tol=1e-8, maxiter=200, scheme=scheme,
                  steps_per_sync=sps, **BK)
        oracle = jpcg_solve_batched(bag, engine="phases", layout="sell",
                                    **kw)
        vm = jpcg_solve_batched(bag, engine="vm", layout="sell", **kw)
        pal = jpcg_solve_batched(bag, engine="vm", layout="sell",
                                 backend="pallas", interpret=True, **kw)
        assert_results_bit_identical(vm, oracle)
        assert_results_bit_identical(pal, oracle)
