"""Differential backend coverage: Pallas (interpret on CPU) vs XLA.

Every precision scheme of the paper's faithful tier is swept through both
backends at two granularities — the bare SpMV and the full JPCG solve —
on random CSR/ELLPACK matrices.  The XLA path is the oracle: the Pallas
kernels must reproduce it to accumulation-dtype tolerance.
"""
import numpy as np
import pytest

from repro.core.cg import jpcg_solve
from repro.core.operators import as_operator
from repro.core.precision import get_scheme
from repro.kernels.ops import ell_operator_pallas
from repro.sparse import (csr_to_dense, diag_dominant_spd, poisson_2d,
                          random_spd)

SCHEMES = ["fp64", "mixed_v1", "mixed_v2", "mixed_v3"]

# matvec agreement tolerance is set by the scheme's accumulate dtype:
# fp32 accumulation (mixed_v1) differs between the two layouts' reduction
# orders at ~1e-6 relative; fp64 accumulation pins them much tighter.
_MV_RTOL = {"fp64": 1e-13, "mixed_v1": 2e-5, "mixed_v2": 1e-7,
            "mixed_v3": 1e-7}


def _matrices():
    return [
        diag_dominant_spd(200, nnz_per_row=10, dominance=1.2, seed=3),
        poisson_2d(18),
        random_spd(96, cond=500.0, seed=11),
    ]


class TestSpMVDifferential:
    @pytest.mark.parametrize("scheme", SCHEMES)
    @pytest.mark.parametrize("mi", range(3))
    def test_pallas_matches_xla_spmv(self, scheme, mi):
        a = _matrices()[mi]
        sch = get_scheme(scheme)
        rng = np.random.default_rng(100 + mi)
        x = rng.standard_normal(a.shape[0])
        op_x = as_operator(a, sch, block_rows=8, col_tile=128)
        op_p = ell_operator_pallas(a, sch, block_rows=128, col_tile=128,
                                   interpret=True)
        import jax.numpy as jnp
        xv = jnp.asarray(x).astype(sch.vector_dtype)
        y_x = np.asarray(op_x.matvec(xv))
        y_p = np.asarray(op_p.matvec(xv))
        scale = np.abs(y_x).max() + 1.0
        np.testing.assert_allclose(y_p / scale, y_x / scale,
                                   rtol=_MV_RTOL[scheme],
                                   atol=_MV_RTOL[scheme])


class TestFullSolveDifferential:
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_pallas_matches_xla_solve(self, scheme):
        a = diag_dominant_spd(300, nnz_per_row=8, dominance=1.2, seed=7)
        r_x = jpcg_solve(a, backend="xla", scheme=scheme, tol=1e-12,
                         maxiter=2000, block_rows=8, col_tile=128)
        r_p = jpcg_solve(a, backend="pallas", scheme=scheme, tol=1e-12,
                         maxiter=2000, block_rows=128, col_tile=128)
        assert r_x.converged and r_p.converged
        # fp32 accumulation may shift the convergence point by an iteration
        assert abs(r_x.iterations - r_p.iterations) <= \
            (0 if scheme in ("fp64", "mixed_v2", "mixed_v3") else 2)
        np.testing.assert_allclose(np.asarray(r_p.x), np.asarray(r_x.x),
                                   rtol=1e-4, atol=1e-6)
        # and both actually solve the system
        d = csr_to_dense(a)
        b = np.ones(a.shape[0])
        for r in (r_x, r_p):
            assert np.linalg.norm(d @ np.asarray(r.x) - b) <= \
                1e-5 * np.linalg.norm(b)

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_batched_backends_agree(self, scheme):
        """The batched engine's two backends agree lane-for-lane too."""
        from repro.core.batch import jpcg_solve_batched
        probs = [poisson_2d(12), diag_dominant_spd(150, nnz_per_row=6,
                                                   dominance=1.4, seed=5)]
        r_x = jpcg_solve_batched(probs, scheme=scheme, tol=1e-12,
                                 maxiter=1000, block_rows=8, col_tile=128,
                                 backend="xla")
        r_p = jpcg_solve_batched(probs, scheme=scheme, tol=1e-12,
                                 maxiter=1000, block_rows=128, col_tile=128,
                                 backend="pallas", interpret=True)
        for a, b in zip(r_x, r_p):
            assert a.converged and b.converged
            assert abs(a.iterations - b.iterations) <= \
                (0 if scheme in ("fp64", "mixed_v2", "mixed_v3") else 2)
            np.testing.assert_allclose(np.asarray(b.x), np.asarray(a.x),
                                       rtol=1e-4, atol=1e-6)
