"""Schedule→program compiler + batched stream VM (the single backend).

Locks the ISSUE-2 pipeline: vsr.schedule → compile → batched VM → engine.

* word-identity: the compiler reproduces the hand assembly exactly for
  the paper policy (``assemble_jpcg`` is the golden reference);
* traffic: compiled programs' derived Type-III memory streams equal the
  §5.5 VSR accounting for both policies (14 = 10R+4W, 13 = 9R+4W);
* bit-identity: VM lane results are bit-equal to the phase-fused batched
  engine across all faithful-tier precision schemes, with per-lane
  on-the-fly termination;
* no-retrace: with ``specialize=False`` one jitted VM executable runs
  paper, min-traffic, and plain-CG programs (compile-cache entries and
  jit trace counts stay flat when only the program operand changes);
* specialization (ISSUE 6): the default path unrolls the concrete
  program into straight-line ops at trace time — bit-identical to the
  generic VM and the phases oracle, cached per program *bytes*.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.batch import (batch_cache_clear, batch_cache_info,
                              jpcg_solve_batched)
from repro.core.cg import jpcg_solve
from repro.core.compile import (PLAIN_CG_MODULES, CompileError,
                                canonical_length, canonical_program,
                                compile_policy, compile_schedule)
from repro.core.isa import (ITYPE_NOP, assemble_jpcg, decode_program,
                            derived_mem_instructions, program_text)
from repro.core.vm import vm_executable_stats, vm_solve
from repro.core.vsr import access_counts, schedule
from repro.sparse import (csr_to_dense, diag_dominant_spd, poisson_2d,
                          tridiagonal_spd)

BK = dict(block_rows=8, col_tile=128)


def _bag():
    """Heterogeneous SPD systems sharing one bucket-able shape range."""
    return [poisson_2d(16), tridiagonal_spd(300),
            diag_dominant_spd(200, nnz_per_row=8, dominance=1.3, seed=2)]


# ------------------------------------------------------------- compiler
class TestCompiler:
    def test_paper_program_word_identical_to_hand_assembly(self):
        """The tentpole lock: compiling vsr.schedule(policy="paper") must
        reproduce the golden hand assembly word for word."""
        ref, _ = assemble_jpcg("paper")
        got = compile_policy("paper").program
        assert np.array_equal(got, ref), (
            "compiled paper program drifted from assemble_jpcg:\n"
            f"compiled:\n{program_text(got)}\nreference:\n"
            f"{program_text(ref)}")

    @pytest.mark.parametrize("policy,reads,writes", [("paper", 10, 4),
                                                     ("min_traffic", 9, 4)])
    def test_derived_traffic_matches_vsr_accounting(self, policy, reads,
                                                    writes):
        """§5.5: the compiled program's Type-III InstRdWr stream equals
        vsr.access_counts — 14 = 10R+4W paper, 13 = 9R+4W min-traffic."""
        mem = derived_mem_instructions(compile_policy(policy).program)
        assert mem == {"reads": reads, "writes": writes,
                       "total": reads + writes}
        assert mem["reads"] == access_counts()[policy]["reads"]
        assert mem["writes"] == access_counts()[policy]["writes"]

    def test_canonical_programs_share_one_length(self):
        L = canonical_length()
        for pol in ("paper", "min_traffic"):
            prog = canonical_program(pol)
            assert prog.shape == (L, 8)
            pad = prog[compile_policy(pol).length:]
            assert (pad[:, 0] == ITYPE_NOP).all()

    def test_decode_roundtrip(self):
        enc = compile_policy("paper").program
        again = np.asarray([i.encode() for i in decode_program(enc)],
                           np.int32)
        assert np.array_equal(enc, again)
        assert "M1_spmv" in program_text(enc)

    def test_traffic_validation_rejects_tampered_schedule(self):
        """The compiler refuses a schedule whose HBM plan it cannot
        implement — emitted traffic is validated phase by phase."""
        s = schedule(policy="paper")
        bad = dataclasses.replace(
            s, hbm_reads=(("p",),) + s.hbm_reads[1:])  # claims 1 read, needs 2
        with pytest.raises(CompileError):
            compile_schedule(bad)

    def test_unknown_module_rejected(self):
        """A schedule naming a module outside the M1–M8 ISA vocabulary
        cannot be lowered."""
        s = schedule(policy="min_traffic")
        bad = dataclasses.replace(s, phases=(("M9_mystery",),) + s.phases[1:])
        with pytest.raises(CompileError):
            compile_schedule(bad)

    def test_plain_cg_module_graph_compiles(self):
        """The compiler serves module graphs beyond the paper's: plain CG
        drops M5 and lowers to 11 accesses (7R + 4W)."""
        cp = compile_policy("min_traffic", PLAIN_CG_MODULES)
        assert derived_mem_instructions(cp.program) == {
            "reads": 7, "writes": 4, "total": 11}


# ------------------------------------------------------ batched stream VM
@pytest.mark.vm
class TestBatchedVM:
    @pytest.mark.parametrize("scheme", ["fp64", "mixed_v1", "mixed_v2",
                                        "mixed_v3"])
    def test_vm_bit_identical_to_phases_engine(self, scheme):
        """Per-lane VM results (x, iterations, rr) are BIT-identical to
        the phase-fused batched engine under every faithful-tier scheme —
        the compiled program executes the same arithmetic in the same
        order as vsr_iteration."""
        probs = _bag()
        vm = jpcg_solve_batched(probs, tol=1e-12, maxiter=400,
                                scheme=scheme, **BK)
        ph = jpcg_solve_batched(probs, tol=1e-12, maxiter=400,
                                scheme=scheme, engine="phases", **BK)
        for v, p in zip(vm, ph):
            assert v.iterations == p.iterations
            assert v.rr == p.rr
            assert np.array_equal(np.asarray(v.x), np.asarray(p.x))
            assert v.converged == p.converged

    def test_per_lane_on_the_fly_termination(self):
        """Lanes terminate at their own tolerance mid-batch; traces are
        bit-equal to the phases engine and stop at each lane's count."""
        easy = tridiagonal_spd(256, off=-0.1)
        hard = tridiagonal_spd(256)
        vm = jpcg_solve_batched([easy, hard], tol=1e-12, maxiter=1000,
                                with_trace=True, **BK)
        ph = jpcg_solve_batched([easy, hard], tol=1e-12, maxiter=1000,
                                with_trace=True, engine="phases", **BK)
        assert vm[0].iterations < vm[1].iterations
        for v, p in zip(vm, ph):
            assert v.iterations == p.iterations
            assert np.array_equal(v.residual_trace, p.residual_trace)
        assert vm[0].residual_trace.shape[0] == vm[0].iterations
        assert vm[0].residual_trace[-1] <= 1e-12

    def test_policies_produce_identical_iterates(self):
        """paper vs min-traffic schedules differ only in HBM traffic, not
        arithmetic: the VM produces bit-equal lanes under both."""
        probs = _bag()
        a = jpcg_solve_batched(probs, tol=1e-12, maxiter=2000,
                               policy="paper", **BK)
        b = jpcg_solve_batched(probs, tol=1e-12, maxiter=2000,
                               policy="min_traffic", **BK)
        for ra, rb in zip(a, b):
            assert ra.iterations == rb.iterations
            assert np.array_equal(np.asarray(ra.x), np.asarray(rb.x))

    def test_vm_matches_single_system_loop(self):
        """Against jpcg_loop (single-system, jnp.dot reductions): same
        solution to scheme tolerance, iteration counts within ±1 — the
        only daylight is dot-reduction order inside XLA."""
        a = poisson_2d(24)
        prog = canonical_program("min_traffic")
        out = vm_solve(a, program=prog, tol=1e-12, maxiter=3000,
                       block_rows=64, col_tile=128)
        ref = jpcg_solve(a, tol=1e-12, maxiter=3000, block_rows=64,
                         col_tile=128)
        assert abs(out["iterations"] - ref.iterations) <= 1
        np.testing.assert_allclose(np.asarray(out["x"]),
                                   np.asarray(ref.x), rtol=1e-8, atol=1e-10)

    def test_plain_cg_program_on_unit_diag_system(self):
        """Plain CG ≡ JPCG when M = I: the compiled plain-CG program must
        bit-match the phases engine on a unit-diagonal system (division
        by exactly 1.0 is lossless)."""
        a = csr_to_dense(poisson_2d(12)) / 4.0      # poisson diag is 4
        prog = compile_policy("min_traffic", PLAIN_CG_MODULES).program
        out = vm_solve(a, program=prog, tol=1e-12, maxiter=2000, **BK)
        ref = jpcg_solve_batched([a], tol=1e-12, maxiter=2000,
                                 engine="phases", **BK)[0]
        assert out["iterations"] == ref.iterations
        assert np.array_equal(np.asarray(out["x"]), np.asarray(ref.x))


# -------------------------------------------------- compile-cache keying
@pytest.mark.vm
class TestNoRetrace:
    def test_one_executable_runs_both_policies(self):
        """Acceptance lock for the generic fallback: with
        ``specialize=False`` the VM executable is keyed on (bucket,
        backend, scheme) — NOT the program.  Running a second policy adds
        neither a cache entry nor a jit trace."""
        batch_cache_clear()
        probs = _bag()
        jpcg_solve_batched(probs, tol=1e-12, maxiter=500,
                           policy="paper", specialize=False, **BK)
        info1, stats1 = batch_cache_info(), vm_executable_stats()
        assert info1["entries"] == 1 and info1["misses"] == 1
        assert stats1 == {"executables": 1, "specialized": 0,
                          "generic": 1, "traces": 1}
        jpcg_solve_batched(probs, tol=1e-12, maxiter=500,
                           policy="min_traffic", specialize=False, **BK)
        info2, stats2 = batch_cache_info(), vm_executable_stats()
        assert info2["entries"] == 1                   # same executable
        assert info2["hits"] == info1["hits"] + 1
        assert stats2 == stats1                        # no retrace

    def test_scheme_change_costs_one_executable(self):
        batch_cache_clear()
        probs = [poisson_2d(12), tridiagonal_spd(200)]
        jpcg_solve_batched(probs, tol=1e-12, maxiter=300, scheme="mixed_v3",
                           specialize=False, **BK)
        jpcg_solve_batched(probs, tol=1e-12, maxiter=300, scheme="fp64",
                           specialize=False, **BK)
        assert vm_executable_stats() == {"executables": 2, "specialized": 0,
                                         "generic": 2, "traces": 2}


# ------------------------------------------- program-specialized VM path
@pytest.mark.vm
class TestSpecializedPath:
    """The production dispatch path (ISSUE 6): the concrete program is
    unrolled into the executable at trace time — straight-line jnp ops,
    no lax.switch over instruction words — and cached per program
    *bytes* (``CompiledProgram.cache_token``)."""

    @pytest.mark.parametrize("scheme", ["fp64", "mixed_v1", "mixed_v2",
                                        "mixed_v3"])
    def test_spec_bit_identical_to_generic_and_phases(self, scheme):
        """Specialization may change dispatch, never arithmetic: the
        specialized path is BIT-identical to the generic traced-operand
        VM and to the phases oracle under every faithful-tier scheme."""
        probs = _bag()
        kw = dict(tol=1e-12, maxiter=400, scheme=scheme, **BK)
        spec = jpcg_solve_batched(probs, **kw)                 # default
        gen = jpcg_solve_batched(probs, specialize=False, **kw)
        ph = jpcg_solve_batched(probs, engine="phases", **kw)
        for s, g, p in zip(spec, gen, ph):
            assert s.iterations == g.iterations == p.iterations
            assert s.rr == g.rr == p.rr
            assert np.array_equal(np.asarray(s.x), np.asarray(g.x))
            assert np.array_equal(np.asarray(s.x), np.asarray(p.x))
            assert s.converged == p.converged

    def test_spec_bit_identical_on_pallas_backend(self):
        """Same lock on the pallas kernel backend (interpret mode on
        CPU) — small problems keep the interpreter affordable."""
        probs = [poisson_2d(8), tridiagonal_spd(100)]
        kw = dict(tol=1e-10, maxiter=200, backend="pallas", **BK)
        spec = jpcg_solve_batched(probs, **kw)
        gen = jpcg_solve_batched(probs, specialize=False, **kw)
        for s, g in zip(spec, gen):
            assert s.iterations == g.iterations
            assert np.array_equal(np.asarray(s.x), np.asarray(g.x))

    def test_word_identical_programs_share_one_executable(self):
        """The specialized cache is keyed on program BYTES, not on how
        the program was named: policy="paper" and an explicitly passed
        canonical paper program hit the same executable."""
        batch_cache_clear()
        probs = _bag()
        jpcg_solve_batched(probs, tol=1e-12, maxiter=500,
                           policy="paper", **BK)
        s1 = vm_executable_stats()
        assert s1 == {"executables": 1, "specialized": 1,
                      "generic": 0, "traces": 1}
        jpcg_solve_batched(probs, tol=1e-12, maxiter=500,
                           program=canonical_program("paper"), **BK)
        assert vm_executable_stats() == s1      # byte-equal ⇒ cache hit
        assert batch_cache_info()["hits"] >= 1

    def test_new_program_words_cost_one_specialized_executable(self):
        """Swapping policies costs one *specialized* executable (the
        words differ even at equal padded length) while the generic
        fallback still serves both policies from ONE executable."""
        batch_cache_clear()
        probs = _bag()
        kw = dict(tol=1e-12, maxiter=500, **BK)
        jpcg_solve_batched(probs, policy="paper", **kw)
        jpcg_solve_batched(probs, policy="min_traffic", **kw)
        s = vm_executable_stats()
        assert s["specialized"] == 2 and s["generic"] == 0
        jpcg_solve_batched(probs, policy="paper", specialize=False, **kw)
        jpcg_solve_batched(probs, policy="min_traffic", specialize=False,
                           **kw)
        s2 = vm_executable_stats()
        assert s2["generic"] == 1               # one serves both
        assert s2["specialized"] == 2           # unchanged
        assert s2["executables"] == 3 and s2["traces"] == 3

    def test_cache_token_is_stable_across_compiles(self):
        """CompiledProgram.cache_token depends only on the padded words:
        recompiling the same policy yields the same token; different
        policies yield different tokens."""
        a = compile_policy("paper").cache_token
        b = compile_policy("paper").cache_token
        c = compile_policy("min_traffic").cache_token
        assert a == b and a != c
        # The runner/stepper caches hash the *padded* words (what runs):
        # equal padded shape, different words ⇒ different tokens there too.
        from repro.core.isa import program_token
        pa = canonical_program("paper")
        pm = canonical_program("min_traffic")
        assert pa.shape == pm.shape
        assert program_token(pa) != program_token(pm)
        assert program_token(pa) == program_token(np.array(pa))

    def test_executable_stats_accounting(self):
        """vm_executable_stats splits the cache into specialized vs
        generic entries and the totals add up."""
        batch_cache_clear()
        assert vm_executable_stats() == {"executables": 0, "specialized": 0,
                                         "generic": 0, "traces": 0}
        probs = _bag()
        jpcg_solve_batched(probs, tol=1e-12, maxiter=300, **BK)
        jpcg_solve_batched(probs, tol=1e-12, maxiter=300,
                           specialize=False, **BK)
        s = vm_executable_stats()
        assert s["specialized"] == 1 and s["generic"] == 1
        assert s["executables"] == s["specialized"] + s["generic"] == 2
