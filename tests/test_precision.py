"""Mixed precision (paper §6, Table 1/7, Fig. 9) — the reproduction's
core claims:

* Mix-V3 (fp32 matrix, fp64 vectors) matches default-FP64 iteration
  counts within a few iterations (Table 7: |diff| ≤ O(10));
* Mix-V1 (all fp32) stalls or diverges on hard problems (Fig. 9);
* the V1 ≤ V2 ≤ V3 quality ordering holds;
* the TPU tier (bf16/fp32) reproduces the same ordering one level down.
"""
import numpy as np
import pytest

from repro.core.cg import jpcg_solve
from repro.core.precision import SCHEMES, get_scheme
from repro.sparse import diag_dominant_spd, poisson_2d, tridiagonal_spd


def _iters(a, scheme, **kw):
    res = jpcg_solve(a, scheme=scheme, tol=1e-12, maxiter=20_000,
                     block_rows=64, col_tile=128, **kw)
    return res


class TestSchemeTable:
    def test_paper_table1(self):
        """Table 1 exactly: storage/compute dtypes per scheme."""
        import jax.numpy as jnp
        v3 = get_scheme("mixed_v3")
        assert v3.matrix_dtype == jnp.float32
        assert v3.spmv_in_dtype == jnp.float64
        assert v3.spmv_acc_dtype == jnp.float64
        assert v3.vector_dtype == jnp.float64
        v1 = get_scheme("mixed_v1")
        assert v1.spmv_acc_dtype == jnp.float32
        assert v1.vector_dtype == jnp.float64   # main loop ALWAYS fp64
        assert get_scheme("mixed_v2").spmv_acc_dtype == jnp.float64

    def test_challenge3_bit_arithmetic(self):
        """§2.3.3 adapted to the stacked layouts: one value at
        matrix_dtype + one local column index per slot (int16 while the
        bucketed n stays under 2^15): 10B/6B/4B per nonzero, 12B/8B/6B
        with int32 indices."""
        assert get_scheme("fp64").nonzero_stream_bytes(index_bytes=4) == 12
        assert get_scheme("fp64").nonzero_stream_bytes() == 10
        assert get_scheme("mixed_v3").nonzero_stream_bytes() == 6
        assert get_scheme("tpu_v3").nonzero_stream_bytes() == 4
        assert get_scheme("mixed_v3").nonzero_stream_bytes(index_bytes=4) == 8

    def test_stream_bytes_match_packed_arrays(self):
        """The model is true by construction: an unpadded matrix's
        stacked arrays stream exactly nonzero_stream_bytes per nnz."""
        from repro.sparse import stack_rowell, tridiagonal_spd
        sch = get_scheme("mixed_v3")
        a = tridiagonal_spd(66)          # constant row width: no padding
        st = stack_rowell([a], scheme=sch)
        interior = 3 * 64                # bucket pads rows 66->128
        assert st.vals.dtype == np.dtype(np.float32)
        assert st.cols.dtype == np.dtype(np.int16)
        per_slot = st.vals.dtype.itemsize + st.cols.dtype.itemsize
        assert per_slot == sch.nonzero_stream_bytes(
            index_bytes=st.index_bytes)
        assert interior > 0              # smoke: the bag wasn't empty


class TestTable7Parity:
    """Mix-V3 iteration counts track FP64 within ±10 (paper Table 7)."""

    @pytest.mark.parametrize("make", [
        lambda: poisson_2d(48),
        lambda: tridiagonal_spd(2048),
        lambda: diag_dominant_spd(3000, nnz_per_row=24, dominance=1.2,
                                  seed=3),
    ])
    def test_v3_matches_fp64(self, make):
        a = make()
        r64 = _iters(a, "fp64")
        rv3 = _iters(a, "mixed_v3")
        assert r64.converged and rv3.converged
        assert abs(rv3.iterations - r64.iterations) <= 10, (
            rv3.iterations, r64.iterations)

    def test_solution_quality(self):
        from repro.sparse import csr_to_dense
        a = poisson_2d(32)
        d = csr_to_dense(a)
        b = np.ones(a.shape[0])
        x = np.asarray(_iters(a, "mixed_v3").x)
        assert np.linalg.norm(d @ x - b) < 1e-5


class TestFig9Ordering:
    """Fig. 9: on an ill-conditioned problem (Laplacian, κ ~ N — Jacobi
    cannot fix it, like the paper's gyro_k) V1 degrades while V3 tracks
    FP64 exactly; the iteration ordering is V3 ≤ V2 ≤ V1."""

    @pytest.fixture(scope="class")
    def results(self):
        hard = poisson_2d(100)                   # n = 10 000, κ ≈ 4e3
        return {s: jpcg_solve(hard, scheme=s, tol=1e-12, maxiter=5000,
                              block_rows=128, col_tile=256)
                for s in ("fp64", "mixed_v3", "mixed_v2", "mixed_v1")}

    def test_v3_tracks_fp64(self, results):
        assert results["mixed_v3"].converged
        assert abs(results["mixed_v3"].iterations
                   - results["fp64"].iterations) <= 10

    def test_v1_worse_than_v3(self, results):
        r1, r3 = results["mixed_v1"], results["mixed_v3"]
        # V1 either fails outright or needs substantially more iterations
        assert (not r1.converged) or r1.iterations > r3.iterations + 10, (
            r1.iterations, r3.iterations)

    def test_scheme_ordering(self, results):
        it = {s: (r.iterations if r.converged else 10 ** 9)
              for s, r in results.items()}
        assert it["mixed_v3"] <= it["mixed_v2"] <= it["mixed_v1"]

    def test_v1_true_residual_floor(self):
        """Driving the recurrence far below fp32 resolution, V1's TRUE
        residual ‖A·x−b‖ floors orders of magnitude above FP64's (the
        recurrence rr keeps shrinking — exactly why the paper needs V3 to
        certify fp64-quality solutions)."""
        from repro.sparse import csr_spmv
        a = poisson_2d(48)
        b = np.ones(a.shape[0])

        def true_resid(scheme):
            r = jpcg_solve(a, scheme=scheme, tol=1e-28, maxiter=400,
                           block_rows=64, col_tile=128)
            return np.linalg.norm(csr_spmv(a, np.asarray(r.x)) - b)

        t64 = true_resid("fp64")
        t1 = true_resid("mixed_v1")
        assert t1 > 1e3 * t64, (t1, t64)


class TestTpuTier:
    """The bf16/fp32 tier reproduces the scheme ordering one level down."""

    def test_tpu_v3_converges_fp32_target(self):
        a = poisson_2d(24)
        r = jpcg_solve(a, scheme="tpu_v3", tol=1e-6, maxiter=5000,
                       block_rows=64, col_tile=128)
        assert r.converged

    def test_tpu_v1_worse_than_tpu_v3(self):
        a = diag_dominant_spd(1500, nnz_per_row=16, dominance=1.05, seed=5)
        r1 = jpcg_solve(a, scheme="tpu_v1", tol=1e-8, maxiter=4000,
                        block_rows=64, col_tile=128)
        r3 = jpcg_solve(a, scheme="tpu_v3", tol=1e-8, maxiter=4000,
                        block_rows=64, col_tile=128)
        assert r3.converged
        assert (not r1.converged) or r1.iterations >= r3.iterations


def test_fp64_scheme_requires_x64_flag():
    """Clean error, not silent downcast, when x64 is off (documented)."""
    assert "fp64" in SCHEMES  # flag behavior covered in cg.py; x64 is on here
