"""VSR scheduling (paper §5): phase partition, HBM access accounting."""
import pytest

from repro.core.vsr import JPCG_MODULES, Module, access_counts, schedule


def test_access_counts_match_paper():
    """§5.5: naive 19 (14R+5W); paper VSR 14 (10R+4W); our min-traffic 13."""
    c = access_counts()
    assert c["naive"] == {"reads": 14, "writes": 5, "total": 19}
    assert c["paper"] == {"reads": 10, "writes": 4, "total": 14}
    assert c["min_traffic"] == {"reads": 9, "writes": 4, "total": 13}


def test_access_counts_regression_lock():
    """Regression lock on the §5.5 accounting, stated as bare integers so
    a schedule refactor cannot silently drift them: naive = 14R + 5W = 19,
    paper VSR = 10R + 4W = 14, min-traffic = 9R + 4W = 13.  If one of
    these asserts fires, the *schedule* changed — fix the schedule or
    update the paper-comparison docs, never this test."""
    c = access_counts()
    assert (c["naive"]["reads"], c["naive"]["writes"]) == (14, 5)
    assert (c["paper"]["reads"], c["paper"]["writes"]) == (10, 4)
    assert (c["min_traffic"]["reads"], c["min_traffic"]["writes"]) == (9, 4)
    s_paper = schedule(policy="paper")
    assert (s_paper.n_reads, s_paper.n_writes, s_paper.n_accesses) \
        == (10, 4, 14)
    s_min = schedule(policy="min_traffic")
    assert (s_min.n_reads, s_min.n_writes, s_min.n_accesses) == (9, 4, 13)
    # the min-traffic win over the paper is exactly ONE read (the M4
    # re-run's re-read of r), nothing else
    assert s_paper.n_reads - s_min.n_reads == 1
    assert s_paper.n_writes == s_min.n_writes


def test_three_phases():
    """Fig. 5: scalar deps split the loop into exactly three phases."""
    s = schedule(policy="paper")
    assert len(s.phases) == 3
    # Phase 1: SpMV + pap dot; phase 2 contains M4/M5/M6/M8; phase 3 M7/M3.
    assert "M1_spmv" in s.phases[0] and "M2_dot_pap" in s.phases[0]
    for m in ("M4_upd_r", "M5_div_z", "M6_dot_rz", "M8_dot_rr"):
        assert m in s.phases[1], (m, s.phases)
    assert "M7_upd_p" in s.phases[2] and "M3_upd_x" in s.phases[2]


def test_z_never_stored():
    """§5.3: z is recomputed in phase 3, never written to HBM."""
    for pol in ("paper", "min_traffic"):
        s = schedule(policy=pol)
        assert "z" in s.never_stored
        for w in s.hbm_writes:
            assert "z" not in w


def test_paper_policy_reruns_m4_m5():
    s = schedule(policy="paper")
    assert "M4_upd_r" in s.recomputed and "M5_div_z" in s.recomputed
    # min_traffic drops the M4 re-run (stores r' straight out of phase 2)
    s2 = schedule(policy="min_traffic")
    assert "M4_upd_r" not in s2.recomputed


def test_p_read_twice_phase1():
    """§5.4: the SpMV's gather-ordered read of p cannot be stream-shared
    with M2's row-ordered read — p appears twice in phase-1 reads."""
    s = schedule(policy="paper")
    assert list(s.hbm_reads[0]).count("p") == 2


def test_within_phase_streaming():
    """Vectors produced and consumed in the same phase ride streams."""
    s = schedule(policy="paper")
    assert "r'" in s.streamed[1]          # M4 -> M5/M6/M8 hand-off
    assert "p" in s.streamed[2]           # one read shared by M7 and M3


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        schedule(policy="bogus")


def test_schedule_is_dataflow_derived():
    """The analysis is computed, not hard-wired: removing the
    preconditioner module (plain CG) still yields a legal schedule with
    fewer accesses, and the scalar barrier structure persists."""
    mods = tuple(m for m in JPCG_MODULES if m.name != "M5_div_z")
    # rewire M6/M7 to read r' instead of z
    def rewire(m: Module) -> Module:
        reads = tuple("r'" if v == "z" else v for v in m.reads)
        return Module(m.name, reads, m.writes, m.scalar_out, m.scalar_in,
                      m.heavy)
    mods = tuple(rewire(m) for m in mods)
    s = schedule(mods, policy="min_traffic")
    assert s.n_accesses < 13
    assert len(s.phases) == 3
