"""Fault tolerance: watchdog, retries, failure-injection restart."""
import time

import jax.numpy as jnp
import pytest

from repro.models import init_params
from repro.models.config import ModelConfig
from repro.train import (AdamWConfig, DataConfig, SyntheticLM, Trainer,
                         TrainerConfig, adamw_init, make_train_step)
from repro.train.fault import StepWatchdog, StragglerError, with_retries

CFG = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, head_dim=16,
                  dtype="float32", remat=False)


class TestWatchdog:
    def test_breach_counting(self):
        wd = StepWatchdog(deadline_s=0.01, max_breaches=3)
        for step in range(2):
            with wd.guard(step):
                time.sleep(0.02)
        assert wd.breaches == 2 and wd.consecutive == 2
        with wd.guard(99):
            pass                                 # fast step resets
        assert wd.consecutive == 0

    def test_escalates_after_max(self):
        wd = StepWatchdog(deadline_s=0.005, max_breaches=2)
        with wd.guard(0):
            time.sleep(0.02)
        with pytest.raises(StragglerError):
            with wd.guard(1):
                time.sleep(0.02)

    def test_disabled_without_deadline(self):
        wd = StepWatchdog(None)
        with wd.guard(0):
            time.sleep(0.01)
        assert wd.breaches == 0


class TestRetries:
    def test_transient_fault_recovered(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("transient")
            return "ok"

        assert with_retries(flaky, retries=3) == "ok"
        assert len(calls) == 3

    def test_exhausted_raises(self):
        def always():
            raise RuntimeError("permanent")

        with pytest.raises(RuntimeError, match="permanent"):
            with_retries(always, retries=2)


class TestKillAndRestart:
    def test_mid_run_failure_resumes_identically(self, tmp_path):
        """Inject a crash mid-training; restart from the checkpoint must
        reproduce the uninterrupted trajectory exactly."""
        opt = AdamWConfig(lr=5e-3, state_dtype="float32")
        data = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=4))
        step_fn = make_train_step(CFG, opt=opt)

        def fresh():
            import jax
            p = init_params(CFG, jax.random.PRNGKey(0))
            return p, adamw_init(p, opt)

        # uninterrupted run: 10 steps
        p, o = fresh()
        ref = Trainer(CFG, data, step_fn, p, o,
                      TrainerConfig(total_steps=10, ckpt_every=0,
                                    ckpt_dir=str(tmp_path / "ref"),
                                    log_every=0)).run()

        # crashing run: checkpoint every 4, die at step 6
        p, o = fresh()
        tr = Trainer(CFG, data, step_fn, p, o,
                     TrainerConfig(total_steps=10, ckpt_every=4,
                                   ckpt_dir=str(tmp_path / "c"),
                                   log_every=0))
        try:
            for _ in range(6):
                tr.run(steps=1)
            raise KeyboardInterrupt("simulated preemption")
        except KeyboardInterrupt:
            pass

        # restart: resume at step 4 (last checkpoint), run to 10
        p, o = fresh()
        tr2 = Trainer(CFG, data, step_fn, p, o,
                      TrainerConfig(ckpt_dir=str(tmp_path / "c"),
                                    log_every=0))
        assert tr2.try_resume() and tr2.step == 4
        log2 = tr2.run(steps=6)
        for a, b in zip(log2, ref[4:]):
            assert a["loss"] == b["loss"]
