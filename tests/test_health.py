"""Breakdown detection, lane lifecycle, and engine observability (ISSUE 9).

The property net that locks the health layer down:

* **poisoned bags** — random SPD bags with injected indefinite /
  singular / NaN lanes, across the faithful schemes × {xla, pallas} ×
  {row-major, sell} layouts and both engines: every poisoned lane reports the
  right structured exit, and the *healthy* lanes are bit-identical to a
  detection-off run and to the phases oracle (detection must be free);
* **request lifecycle** — the engine's opt-in fp64 escalation turns a
  mixed-precision breakdown into a converged fp64 result carrying
  ``retried=True``; donation + mid-run compaction preserve statuses;
* **observability** — the exit-status histogram sums to the number of
  submitted requests; the solve runners feed the module-global
  :func:`repro.core.metrics.solver_metrics` with exact SpMV/iteration
  accounting.

Poison constructions (chosen so the breakdown is *exact* in every
precision scheme — no rounding luck):

* ``J_n`` (all-ones, rank 1) with a sum-zero rhs: the first search
  direction lies in the nullspace, ``pAp = 0`` on tick 1 (the ±1
  entries cancel exactly in any float width);
* ``[[1, 2], [2, 1]]`` (eigenvalues 3, −1) embedded in an identity
  block, rhs hitting the indefinite block: ``pAp`` goes negative on the
  *second* tick — detection mid-run, not just at warm-up;
* a NaN-seeded rhs: non-finite at admission.
"""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.batch import jpcg_solve_batched
from repro.core.metrics import (reset_solver_metrics, solver_metrics,
                                tick_health)
from repro.serve.solver_engine import SolverEngine, SolverEngineConfig
from repro.sparse import csr_from_coo, random_spd, tridiagonal_spd
from oracles import assert_lane_equal, assert_statuses

pytestmark = pytest.mark.health

BK = dict(block_rows=8, col_tile=128)
SCHEMES = ["fp64", "mixed_v1", "mixed_v2", "mixed_v3"]
BACKENDS = [("xla", "rowell"), ("xla", "sell"),
            ("pallas", "ellpack"), ("pallas", "sell")]
MAXITER = 200


def _singular_J(n):
    """All-ones matrix (rank 1) + sum-zero rhs -> pAp = 0 on tick 1."""
    i = np.repeat(np.arange(n), n)
    j = np.tile(np.arange(n), n)
    a = csr_from_coo(i, j, np.ones(n * n), (n, n))
    b = np.zeros(n)
    b[0], b[1] = 1.0, -1.0
    return a, b


def _indefinite_block(n):
    """Identity with its last 2×2 replaced by [[1,2],[2,1]] (eig 3, −1),
    rhs = e_{n-2}: the solve stays confined to the indefinite block and
    ``pAp`` turns negative on the second tick."""
    i = np.concatenate([np.arange(n - 2), [n - 2, n - 2, n - 1, n - 1]])
    j = np.concatenate([np.arange(n - 2), [n - 2, n - 1, n - 2, n - 1]])
    v = np.concatenate([np.ones(n - 2), [1.0, 2.0, 2.0, 1.0]])
    a = csr_from_coo(i, j, v, (n, n))
    b = np.zeros(n)
    b[n - 2] = 1.0
    return a, b


def _nan_rhs(n):
    a = tridiagonal_spd(n)
    b = np.ones(n)
    b[0] = np.nan
    return a, b


#: lane index -> expected terminal status for :func:`_poison_bag`.
EXPECTED = {2: "BREAKDOWN_INDEFINITE", 3: "BREAKDOWN_INDEFINITE",
            4: "BREAKDOWN_NONFINITE"}


def _poison_bag(n, seed):
    """2 healthy lanes + singular + mid-run indefinite + NaN rhs."""
    probs = [random_spd(n, cond=50.0, seed=seed), tridiagonal_spd(n)]
    bs = [np.ones(n), np.ones(n)]
    for a, b in (_singular_J(n), _indefinite_block(n), _nan_rhs(n)):
        probs.append(a)
        bs.append(b)
    return probs, bs


def _check_poisoned(results):
    """Shared oracle, specialized to :func:`_poison_bag`'s lane map."""
    assert_statuses(results, EXPECTED, healthy=(0, 1), maxiter=MAXITER)


class TestPoisonedBag:
    """Detection fires with the right diagnosis and costs healthy lanes
    nothing — on every scheme, backend, layout, and engine."""

    @pytest.mark.parametrize("backend,layout", BACKENDS)
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_sweep_statuses_and_bit_identity(self, scheme, backend, layout):
        probs, bs = _poison_bag(24, seed=7)
        kw = dict(tol=1e-10, maxiter=MAXITER, scheme=scheme,
                  backend=backend, layout=layout, **BK)
        if backend == "pallas":
            kw["interpret"] = True
        vm = jpcg_solve_batched(probs, bs, engine="vm", **kw)
        _check_poisoned(vm)
        # Healthy lanes bit-identical to the detection-off run: with
        # detect=False tick_health returns the keep mask itself, so the
        # compiled dataflow is unchanged by construction — this asserts
        # the construction survived both engines' plumbing.
        off = jpcg_solve_batched(probs, bs, engine="vm", detect=False, **kw)
        for g in (0, 1):
            assert off[g].status == "CONVERGED"
            assert_lane_equal(vm[g], off[g], g)
        # Phases oracle: same statuses everywhere, bit-identical lanes
        # (poisoned lanes freeze at the same pre-tick state too).
        ph = jpcg_solve_batched(probs, bs, engine="phases", **kw)
        for g, (v, p) in enumerate(zip(vm, ph)):
            assert_lane_equal(v, p, g, status=True)

    @given(n=st.sampled_from([16, 24, 40]), seed=st.integers(0, 2**16))
    @settings(deadline=None, max_examples=6)
    def test_random_bags_property(self, n, seed):
        """∀ bag: poisoned lanes -> right status, healthy lanes ->
        CONVERGED + bit-identical to detection-off (xla/rowell, both
        engines; the parametrized sweep covers the backend × layout
        grid at a fixed draw)."""
        probs, bs = _poison_bag(n, seed)
        kw = dict(tol=1e-10, maxiter=MAXITER, layout="rowell", **BK)
        for engine in ("vm", "phases"):
            on = jpcg_solve_batched(probs, bs, engine=engine, **kw)
            _check_poisoned(on)
            off = jpcg_solve_batched(probs, bs, engine=engine,
                                     detect=False, **kw)
            for g in (0, 1):
                assert_lane_equal(on[g], off[g], g)

    def test_generic_vm_path_detects(self):
        """The traced-program (specialize=False) VM path carries the
        same status semantics as the unrolled path."""
        probs, bs = _poison_bag(16, seed=3)
        kw = dict(tol=1e-10, maxiter=MAXITER, layout="rowell", **BK)
        gen = jpcg_solve_batched(probs, bs, engine="vm",
                                 specialize=False, **kw)
        _check_poisoned(gen)
        spec = jpcg_solve_batched(probs, bs, engine="vm", **kw)
        for g, (a_, b_) in enumerate(zip(spec, gen)):
            assert_lane_equal(a_, b_, g, status=True)

    def test_with_status_false_is_legacy(self):
        """Satellite c: ``with_status=False`` restores the pre-ISSUE-9
        result surface (status None, repr unchanged) without changing
        the numbers."""
        probs, bs = _poison_bag(16, seed=1)
        kw = dict(tol=1e-10, maxiter=MAXITER, layout="rowell", **BK)
        on = jpcg_solve_batched(probs, bs, **kw)
        off = jpcg_solve_batched(probs, bs, with_status=False, **kw)
        for g, (r1, r0) in enumerate(zip(on, off)):
            assert r1.status is not None
            assert r0.status is None
            assert "status" not in repr(r0)
            assert_lane_equal(r1, r0, g)

    def test_maxiter_vs_breakdown_distinguished(self):
        """A slow-but-healthy lane exhausting its budget is MAXITER,
        not a breakdown — the two failure faces stay separate."""
        a = random_spd(48, cond=1e6, seed=0)
        res = jpcg_solve_batched([a], tol=1e-14, maxiter=3, **BK)
        assert res[0].status == "MAXITER"
        assert not res[0].converged and not res[0].retried


class TestTickHealthAlgebra:
    """Unit semantics of the shared per-tick classifier."""

    def test_detect_off_is_identity(self):
        import jax.numpy as jnp
        keep = jnp.array([True, False, True])
        upd, bi, bn = tick_health(keep, jnp.zeros(3), jnp.zeros(3),
                                  jnp.zeros(3), jnp.zeros(3), detect=False)
        assert upd is keep and bi is None and bn is None

    def test_indefinite_wins_over_nonfinite(self):
        import jax.numpy as jnp
        keep = jnp.array([True, True, True, False])
        pap = jnp.array([0.0, jnp.nan, 1.0, -1.0])
        inf = jnp.array([jnp.inf, jnp.nan, jnp.inf, 1.0])
        upd, bi, bn = tick_health(keep, pap, inf, inf, inf, detect=True)
        # lane 0: pAp = 0 with Inf alpha -> the indefiniteness is the
        # diagnosis; lane 1: NaN pAp fails the <=0 compare -> nonfinite;
        # lane 2: healthy-but-nonfinite scalars -> nonfinite; lane 3:
        # already frozen, untouched.
        assert bi.tolist() == [True, False, False, False]
        assert bn.tolist() == [False, True, True, False]
        assert upd.tolist() == [False, False, False, False]


class TestEngineLifecycle:
    def test_escalation_retries_breakdown_at_fp64(self):
        """A matrix whose fp32 packing rounds singular breaks down in
        the mixed pool; with ``escalate_fp64`` the engine resubmits it
        once at fp64 under the same request id and returns a converged
        result with ``retried=True``."""
        eps = 1e-9           # 1 - eps rounds to 1.0 in float32
        a = np.array([[1.0, 1.0 - eps], [1.0 - eps, 1.0]])
        eng = SolverEngine(SolverEngineConfig(
            scheme="mixed_v3", batch_slots=4, chunk_iters=8,
            escalate_fp64=True))
        rid = eng.submit(a, np.array([1.0, 0.0]), tol=1e-8, maxiter=50)
        res = eng.run_to_completion()[rid]
        assert res.retried and res.converged
        assert res.scheme == "fp64" and res.status == "CONVERGED"
        m = eng.metrics()
        assert m["escalations"] == 1
        # the escalated first attempt is not a recorded exit — one
        # request, one histogram entry
        assert m["exit_status"] == {"CONVERGED": 1}

    def test_escalation_is_single_shot(self):
        """A genuinely singular operand breaks down at fp64 too: the
        final result is the fp64 breakdown, retried, not a loop."""
        a, b = _singular_J(8)
        eng = SolverEngine(SolverEngineConfig(
            scheme="mixed_v3", batch_slots=4, chunk_iters=8,
            escalate_fp64=True))
        rid = eng.submit(a, b, tol=1e-10, maxiter=50)
        res = eng.run_to_completion()[rid]
        assert res.retried and not res.converged
        assert res.scheme == "fp64"
        assert res.status == "BREAKDOWN_INDEFINITE"
        assert eng.metrics()["escalations"] == 1

    def test_breakdown_status_without_escalation(self):
        a, b = _singular_J(16)
        eng = SolverEngine(SolverEngineConfig(batch_slots=4,
                                              chunk_iters=8))
        rid = eng.submit(a, b, tol=1e-10, maxiter=100)
        res = eng.run_to_completion()[rid]
        assert res.status == "BREAKDOWN_INDEFINITE"
        assert not res.retried and res.iterations < 100

    def test_compaction_preserves_status(self):
        """Easy lanes harvest first, the pool compacts mid-run, and the
        survivors (a long-running lane and a frozen breakdown pending
        harvest) keep their statuses through the shuffle."""
        eng = SolverEngine(SolverEngineConfig(
            batch_slots=8, chunk_iters=4, compact_fraction=0.75))
        rids = {}
        hard = random_spd(32, cond=1e5, seed=2)
        rids[eng.submit(hard, tol=1e-12, maxiter=4000)] = "hard"
        for i in range(4):
            rids[eng.submit(tridiagonal_spd(24, diag=2.0 + 0.2 * i),
                            tol=1e-10, maxiter=500)] = f"easy{i}"
        a, b = _singular_J(24)
        rids[eng.submit(a, b, tol=1e-10, maxiter=500)] = "singular"
        out = eng.run_to_completion()
        assert eng.metrics()["compactions"] >= 1
        for rid, tag in rids.items():
            res = out[rid]
            if tag == "singular":
                assert res.status == "BREAKDOWN_INDEFINITE"
            else:
                assert res.status == "CONVERGED", (tag, res.status)

    def test_histogram_sums_to_submitted(self):
        eng = SolverEngine(SolverEngineConfig(batch_slots=8,
                                              chunk_iters=8))
        n_req = 6
        for i in range(n_req):
            if i == 0:
                a, b = _singular_J(16)
                eng.submit(a, b, tol=1e-10, maxiter=100)
            elif i == 1:
                a, b = _nan_rhs(16)
                eng.submit(a, b, tol=1e-10, maxiter=100)
            else:
                eng.submit(tridiagonal_spd(16, diag=2.0 + 0.1 * i),
                           tol=1e-10, maxiter=500)
        eng.run_to_completion()
        m = eng.metrics()
        assert sum(m["exit_status"].values()) == n_req
        assert m["exit_status"]["BREAKDOWN_INDEFINITE"] == 1
        assert m["exit_status"]["BREAKDOWN_NONFINITE"] == 1
        assert m["exit_status"]["CONVERGED"] == n_req - 2
        assert m["admits"] == n_req and m["harvests"] == n_req
        assert m["iterations"] > 0 and m["bytes_streamed_est"] > 0
        # every pool drained
        for p in m["pools"].values():
            assert p["occupied"] == 0 and p["active"] == 0


class TestSolverMetricsGlobal:
    def test_batched_solve_accounting(self):
        """jpcg_solve_batched feeds the module-global metrics with exact
        event counts: one warm-up per lane, one SpMV per committed
        iteration, one discarded tick per in-loop breakdown."""
        reset_solver_metrics()
        try:
            probs, bs = _poison_bag(16, seed=5)
            res = jpcg_solve_batched(probs, bs, tol=1e-10,
                                     maxiter=MAXITER, layout="rowell",
                                     **BK)
            m = solver_metrics().snapshot()
            assert m["solves"] == 1 and m["lanes"] == len(probs)
            its = sum(r.iterations for r in res)
            assert m["iterations"] == its
            # breakdown lanes: singular + indefinite-block tick once and
            # discard; the NaN-rhs lane is latched at warm-up (no tick)
            assert m["spmv_calls"] == len(probs) + its + 2
            assert m["bytes_streamed_est"] > 0
            assert sum(m["exit_status"].values()) == len(probs)
        finally:
            reset_solver_metrics()
