"""Batched multi-system JPCG: lane-vs-single parity, on-the-fly per-lane
termination, bucket compile-cache reuse, SolverEngine admission."""
import jax
import numpy as np
import pytest

from repro.core.batch import (batch_cache_clear, batch_cache_info,
                              jpcg_solve_batched)
from repro.core.cg import jpcg_solve
from repro.sparse import (csr_to_dense, diag_dominant_spd, poisson_2d,
                          random_spd, tridiagonal_spd)
from repro.sparse.stacking import bucket_up
from repro.serve.solver_engine import SolverEngine, SolverEngineConfig
from oracles import (assert_lane_equal, assert_results_bit_identical,
                     assert_vm_states_equal)

BK = dict(block_rows=8, col_tile=128)


def _mixed_bag():
    """≥8 heterogeneous SPD systems: different n, conditioning, sparsity."""
    return [
        poisson_2d(16),                                                 # 256
        tridiagonal_spd(300),                                           # 300
        diag_dominant_spd(200, nnz_per_row=8, dominance=1.3, seed=2),
        random_spd(64, cond=100.0, seed=1),
        poisson_2d(20),                                                 # 400
        tridiagonal_spd(128, off=-0.4),          # easy: converges early
        diag_dominant_spd(400, nnz_per_row=12, dominance=1.05, seed=5),
        random_spd(100, cond=1e3, seed=9),
    ]


class TestBatchedParity:
    def test_lanes_match_single_solver(self):
        """Each lane of one compiled batched solve reproduces the
        single-system solver: iterations within ±2, x to tolerance."""
        probs = _mixed_bag()
        assert len(probs) >= 8
        res = jpcg_solve_batched(probs, tol=1e-12, maxiter=4000, **BK)
        for a, r in zip(probs, res):
            ref = jpcg_solve(a, tol=1e-12, maxiter=4000, **BK)
            assert r.converged and ref.converged
            # the batched matvec reduces rows through the deterministic
            # halving tree (layout bit-interchangeability), the single
            # solver through banked tiles — different rounding, so the
            # cond≈1e3 lane can stop a step or two apart near ‖r‖²≈tol
            assert abs(r.iterations - ref.iterations) <= 2
            # both stopped at ‖r‖² ≤ 1e-12, i.e. ‖r‖ ≈ 1e-6: the two
            # near-solutions may differ by one final update of that size
            np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                       rtol=1e-4, atol=1e-6)

    @pytest.mark.slow
    def test_solution_solves_system(self):
        probs = _mixed_bag()
        res = jpcg_solve_batched(probs, tol=1e-12, maxiter=4000, **BK)
        for a, r in zip(probs, res):
            d = csr_to_dense(a)
            x = np.asarray(r.x)
            b = np.ones(a.shape[0])
            assert np.linalg.norm(d @ x - b) <= 1e-4 * np.linalg.norm(b)

    @pytest.mark.parametrize("scheme", ["fp64", "mixed_v3"])
    def test_schemes(self, scheme):
        probs = [poisson_2d(12), tridiagonal_spd(200)]
        res = jpcg_solve_batched(probs, tol=1e-12, maxiter=2000,
                                 scheme=scheme, **BK)
        for a, r in zip(probs, res):
            ref = jpcg_solve(a, tol=1e-12, maxiter=2000, scheme=scheme, **BK)
            assert abs(r.iterations - ref.iterations) <= 1

    def test_custom_rhs_x0_and_per_problem_tol(self):
        probs = [poisson_2d(12), poisson_2d(14)]
        rng = np.random.default_rng(0)
        bs = [rng.standard_normal(a.shape[0]) for a in probs]
        d0 = csr_to_dense(probs[0])
        xstar0 = np.linalg.solve(d0, bs[0])
        x0s = [xstar0, np.zeros(probs[1].shape[0])]
        res = jpcg_solve_batched(probs, bs, x0s=x0s,
                                 tol=[1e-10, 1e-12], maxiter=2000, **BK)
        # lane 0 started at its solution: terminates immediately
        assert res[0].iterations <= 1
        assert res[1].converged and res[1].rr <= 1e-12


class TestOnTheFlyTermination:
    def test_early_lane_freezes(self):
        """An easy lane converges early and its x stops updating while the
        hard lane keeps iterating (per-problem termination in one loop)."""
        easy = tridiagonal_spd(256, off=-0.1)
        hard = tridiagonal_spd(256)
        res = jpcg_solve_batched([easy, hard], tol=1e-12, maxiter=1000,
                                 with_trace=True, **BK)
        assert res[0].iterations < res[1].iterations
        # frozen lane's result equals its own single solve (no extra drift
        # from the iterations the batch kept running)
        ref = jpcg_solve(easy, tol=1e-12, maxiter=1000, **BK)
        assert abs(res[0].iterations - ref.iterations) <= 1
        np.testing.assert_allclose(np.asarray(res[0].x), np.asarray(ref.x),
                                   rtol=1e-9)
        # trace stops exactly at the lane's own iteration count
        assert res[0].residual_trace.shape[0] == res[0].iterations
        assert res[0].residual_trace[-1] <= 1e-12

    def test_maxiter_respected_per_batch(self):
        a = diag_dominant_spd(500, nnz_per_row=12, dominance=1.01, seed=1)
        res = jpcg_solve_batched([a, poisson_2d(8)], tol=1e-30, maxiter=7,
                                 **BK)
        assert res[0].iterations == 7 and not res[0].converged
        assert res[1].iterations == 7 and not res[1].converged


class TestBucketCache:
    def test_same_bucket_reuses_runner(self):
        """Two different heterogeneous batches landing in the same bucket
        share one compiled runner (the handful-of-executables goal)."""
        batch_cache_clear()
        jpcg_solve_batched([poisson_2d(12), tridiagonal_spd(200)],
                           tol=1e-12, maxiter=500, **BK)
        info1 = batch_cache_info()
        jpcg_solve_batched([poisson_2d(11), tridiagonal_spd(180)],
                           tol=1e-12, maxiter=500, **BK)
        info2 = batch_cache_info()
        assert info1["entries"] == 1 and info1["misses"] == 1
        assert info2["entries"] == 1 and info2["hits"] == info1["hits"] + 1

    def test_bucket_up_edges(self):
        assert [bucket_up(x) for x in (1, 2, 3, 5, 8, 9)] == \
            [1, 2, 4, 8, 8, 16]


class TestSolverEngine:
    def test_admission_and_harvest(self):
        eng = SolverEngine(SolverEngineConfig(batch_slots=4, chunk_iters=32,
                                              **BK))
        probs = {0: poisson_2d(16), 1: tridiagonal_spd(300),
                 2: diag_dominant_spd(200, nnz_per_row=8, dominance=1.3,
                                      seed=2)}
        ids = {k: eng.submit(a) for k, a in probs.items()}
        eng.step()
        # a slot freed mid-flight admits a new system without disturbing
        # the in-flight lanes — DecodeEngine-style continuous batching
        ids[3] = eng.submit(poisson_2d(20))
        probs[3] = poisson_2d(20)
        eng.run_to_completion()
        for k, a in probs.items():
            ref = jpcg_solve(a, tol=1e-12, maxiter=20_000, **BK)
            got = eng.results[ids[k]]
            assert got.converged
            assert abs(got.iterations - ref.iterations) <= 1
            np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                                       rtol=1e-6, atol=1e-8)

    def test_bucket_growth(self):
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=64,
                                              **BK))
        r1 = eng.submit(poisson_2d(12))
        eng.run_to_completion()
        r2 = eng.submit(poisson_2d(40))     # larger problem: bucket grows
        eng.run_to_completion()
        ref = jpcg_solve(poisson_2d(40), tol=1e-12, maxiter=20_000, **BK)
        assert abs(eng.results[r2].iterations - ref.iterations) <= 1
        assert eng.results[r1].converged and eng.results[r2].converged

    def test_slot_exhaustion_raises(self):
        eng = SolverEngine(SolverEngineConfig(batch_slots=1, **BK))
        eng.submit(poisson_2d(8))
        with pytest.raises(RuntimeError):
            eng.submit(poisson_2d(8))

    def test_per_request_maxiter(self):
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=8,
                                              **BK))
        hard = diag_dominant_spd(500, nnz_per_row=12, dominance=1.01, seed=1)
        rid = eng.submit(hard, tol=1e-30, maxiter=5)
        eng.run_to_completion()
        assert eng.results[rid].iterations == 5
        assert not eng.results[rid].converged

    def test_per_request_policy_shares_executable(self):
        """submit(policy=) routes to a separate pool, but with
        ``specialize=False`` pools differing only in policy share one
        jitted VM stepper — the program is an operand, not part of the
        cache key."""
        from repro.core.vm import vm_executable_stats
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=32,
                                              specialize=False, **BK))
        a = poisson_2d(16)
        r1 = eng.submit(a)                          # cfg default: paper
        eng.step()
        before = vm_executable_stats()
        r2 = eng.submit(a, policy="min_traffic")
        eng.run_to_completion()
        after = vm_executable_stats()
        assert after["traces"] == before["traces"]  # no new trace
        g1, g2 = eng.results[r1], eng.results[r2]
        assert g1.method == "vm_engine[paper]"
        assert g2.method == "vm_engine[min_traffic]"
        # same arithmetic, different traffic schedule: identical results
        assert g1.iterations == g2.iterations
        np.testing.assert_array_equal(np.asarray(g1.x), np.asarray(g2.x))

    def test_per_request_policy_costs_one_specialized_stepper(self):
        """Under the default specialized path a new policy costs exactly
        one specialized stepper (its program bytes differ) and leaves the
        generic-executable count untouched; results are still identical
        across policies."""
        from repro.core.vm import vm_executable_stats
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=32,
                                              **BK))
        a = poisson_2d(16)
        r1 = eng.submit(a)
        eng.step()
        before = vm_executable_stats()
        r2 = eng.submit(a, policy="min_traffic")
        eng.run_to_completion()
        after = vm_executable_stats()
        assert after["specialized"] == before["specialized"] + 1
        assert after["generic"] == before["generic"]
        g1, g2 = eng.results[r1], eng.results[r2]
        assert g1.iterations == g2.iterations
        np.testing.assert_array_equal(np.asarray(g1.x), np.asarray(g2.x))

    @pytest.mark.parametrize("specialize", [True, False])
    def test_bucket_growth_preserves_inflight_queues(self, specialize):
        """Regression (ISSUE 6): growing the bucket mid-flight must copy
        the queue file like ``mem`` — it used to be silently reset to
        zeros, corrupting any program that keeps streams live across
        iterations.  Also checks the in-flight lane still converges to
        the single-solver answer after growth.

        The two paths exercise different contracts (ISSUE 7): the
        generic stepper executes queue ops against the full state, so
        live streams are nonzero and must survive growth; the
        specialized stepper's dead-state analysis proves the canonical
        programs' queues phase-local — they *pass through* untouched
        (stay zero), which growth must likewise preserve."""
        eng = SolverEngine(SolverEngineConfig(
            batch_slots=2, chunk_iters=8, specialize=specialize, **BK))
        hard = tridiagonal_spd(300)
        r1 = eng.submit(hard)
        eng.step()                           # 8 iterations: queues live
        pool = eng._pool(None, None)
        assert bool(pool.state.active[0])    # still in flight
        q_before = np.asarray(pool.state.queues)
        if specialize:
            # pass-through contract: no live-in queues → bit-stable zeros
            assert np.all(q_before == 0.0)
        else:
            assert np.any(q_before != 0.0)
        m_before = np.asarray(pool.state.mem)

        r2 = eng.submit(poisson_2d(40))      # larger problem: bucket grows
        old_n = q_before.shape[-1]
        q_after = np.asarray(eng._pool(None, None).state.queues)
        assert q_after.shape[-1] > old_n
        # the in-flight lane's streams survived the grow (slot 0)
        assert np.array_equal(q_after[:, 0, :old_n], q_before[:, 0])
        assert np.all(q_after[:, 0, old_n:] == 0.0)
        assert np.array_equal(
            np.asarray(eng._pool(None, None).state.mem)[:, 0, :old_n],
            m_before[:, 0])

        eng.run_to_completion()
        for rid, a in ((r1, hard), (r2, poisson_2d(40))):
            ref = jpcg_solve(a, tol=1e-12, maxiter=20_000, **BK)
            got = eng.results[rid]
            assert got.converged
            assert abs(got.iterations - ref.iterations) <= 1
            np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                                       rtol=1e-6, atol=1e-8)

    @pytest.mark.parametrize("specialize", [True, False])
    def test_frozen_slot_state_is_bit_stable(self, specialize):
        """Regression (ISSUE 6): once a slot converges, its entire VM
        state — mem, queues, sregs, it — must be bit-stable while other
        slots keep iterating (``chunk_iters=1`` pins the check to the
        tick right after convergence, where the unmasked queue write
        drifted)."""
        eng = SolverEngine(SolverEngineConfig(
            batch_slots=2, chunk_iters=1, specialize=specialize, **BK))
        eng.submit(tridiagonal_spd(128, off=-0.1))   # easy: freezes first
        eng.submit(tridiagonal_spd(256))             # hard: keeps going
        pool = eng._pool(None, None)
        while bool(pool.state.active[0]) and bool(pool.state.active[1]):
            eng.step()
        frozen = 0 if not bool(pool.state.active[0]) else 1
        assert bool(pool.state.active[1 - frozen])
        snap = {f: np.asarray(getattr(pool.state, f))
                for f in ("mem", "queues", "sregs", "it")}
        eng.step()
        assert_vm_states_equal(pool.state, snap, lane=frozen)

    def test_free_slots_sums_across_pools(self):
        """free_slots() counts capacity across every instantiated pool
        (it used to see only the default pool); ``pool=`` restores the
        single-pool view and an uninstantiated pool reports its full
        capacity."""
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, **BK))
        assert eng.free_slots() == 2                 # nothing materialized
        eng.submit(poisson_2d(8))                    # default pool
        eng.submit(poisson_2d(8), scheme="fp64")     # second pool
        assert eng.free_slots() == 2                 # one free in each
        assert eng.free_slots(pool=(None, None)) == 1
        assert eng.free_slots(pool=("fp64", None)) == 1
        assert eng.free_slots(pool=(None, "min_traffic")) == 2
        eng.run_to_completion()
        assert eng.free_slots() == 4                 # both pools drained

    def test_per_request_scheme(self):
        """submit(scheme=) solves that request at its own precision; the
        result records the scheme and matches the single-system solver."""
        eng = SolverEngine(SolverEngineConfig(batch_slots=2, chunk_iters=64,
                                              scheme="mixed_v3", **BK))
        a = tridiagonal_spd(200)
        r64 = eng.submit(a, scheme="fp64")
        rv3 = eng.submit(a)
        eng.run_to_completion()
        assert eng.results[r64].scheme == "fp64"
        assert eng.results[rv3].scheme == "mixed_v3"
        for rid, scheme in ((r64, "fp64"), (rv3, "mixed_v3")):
            ref = jpcg_solve(a, tol=1e-12, maxiter=20_000, scheme=scheme,
                             **BK)
            assert abs(eng.results[rid].iterations - ref.iterations) <= 1
            np.testing.assert_allclose(np.asarray(eng.results[rid].x),
                                       np.asarray(ref.x), rtol=1e-6,
                                       atol=1e-8)


class TestIterationChunking:
    """ISSUE 7: ``steps_per_sync`` runs k iterations per termination
    sync; every observable must stay bit-identical to k=1."""

    CHUNKS = (4, 8)

    def _solve(self, probs, k, *, engine, maxiter=2000, tol=1e-12, **kw):
        return jpcg_solve_batched(probs, tol=tol, maxiter=maxiter,
                                  with_trace=True, engine=engine,
                                  steps_per_sync=k, **kw, **BK)

    @pytest.mark.parametrize("engine,kw", [
        ("phases", {}),
        ("vm", {"specialize": True}),
        ("vm", {"specialize": False}),
    ])
    def test_chunk_sizes_bit_identical(self, engine, kw):
        """Per-lane solutions, iteration counts, final ‖r‖² and full
        residual traces agree bitwise across k ∈ {1, 4, 8} — including a
        lane that converges mid-chunk (the easy tridiagonal)."""
        probs = [poisson_2d(12), tridiagonal_spd(300),
                 tridiagonal_spd(128, off=-0.4)]
        base = self._solve(probs, 1, engine=engine, **kw)
        for k in self.CHUNKS:
            res = self._solve(probs, k, engine=engine, **kw)
            assert_results_bit_identical(res, base, rr=True, trace=True)

    @pytest.mark.parametrize("engine,kw", [
        ("phases", {}),
        ("vm", {"specialize": True}),
    ])
    def test_maxiter_not_multiple_of_chunk(self, engine, kw):
        """A lane that hits ``maxiter`` mid-chunk must stop at exactly
        ``maxiter`` iterations (never overshoot to the chunk edge) and
        report the same truncated trace for every k."""
        probs = [tridiagonal_spd(300)]
        base = self._solve(probs, 1, engine=engine, maxiter=37,
                           tol=1e-30, **kw)
        assert base[0].iterations == 37 and not base[0].converged
        for k in self.CHUNKS:
            res = self._solve(probs, k, engine=engine, maxiter=37,
                              tol=1e-30, **kw)
            assert res[0].iterations == 37
            assert_lane_equal(res[0], base[0], 0, rr=True, trace=True)


class TestDonationAndCompaction:
    """ISSUE 7: donated steppers must not invalidate harvested results;
    converged-lane compaction repacks without touching live lanes."""

    def test_harvested_results_survive_donating_steps(self):
        """harvest() hands out host copies: results collected while
        other lanes keep stepping (donating the pool state each tick)
        stay bit-stable through completion."""
        eng = SolverEngine(SolverEngineConfig(
            batch_slots=4, chunk_iters=8, donate=True, **BK))
        r_easy = eng.submit(tridiagonal_spd(128, off=-0.1))
        r_hard = eng.submit(tridiagonal_spd(400))
        while r_easy not in eng.results:
            eng.step()
        x = eng.results[r_easy].x
        assert isinstance(x, np.ndarray)         # host copy, not a view
        snap = x.copy()
        eng.run_to_completion()                  # more donating steps
        np.testing.assert_array_equal(eng.results[r_easy].x, snap)
        assert eng.results[r_hard].converged

    def test_results_independent_of_donation(self):
        """donate on/off is invisible in results — same x bitwise."""
        probs = [poisson_2d(12), tridiagonal_spd(200)]
        outs = []
        for donate in (False, True):
            eng = SolverEngine(SolverEngineConfig(
                batch_slots=2, chunk_iters=16, donate=donate, **BK))
            rids = [eng.submit(a) for a in probs]
            eng.run_to_completion()
            outs.append([eng.results[r] for r in rids])
        assert_results_bit_identical(outs[1], outs[0])

    def test_compaction_shrinks_pool_and_preserves_results(self):
        """Seven easy lanes converge early; once they harvest, the pool
        repacks the surviving lane into the smallest bucket — and the
        survivor's result is bit-identical to a never-compacting run."""
        def build(compact_fraction):
            eng = SolverEngine(SolverEngineConfig(
                batch_slots=8, chunk_iters=8,
                compact_fraction=compact_fraction, **BK))
            easies = [eng.submit(tridiagonal_spd(64 + 8 * i, off=-0.1))
                      for i in range(7)]
            hard = eng.submit(tridiagonal_spd(500))
            return eng, easies, hard

        eng, easies, hard = build(0.5)
        pool = eng._pool(None, None)
        compacted = False
        while pool.any_active:
            eng.step()
            compacted = compacted or pool.slots < 8
        assert compacted and pool.slots < 8
        assert pool.state.mem.shape[1] == pool.slots

        # compact_fraction=0 disables compaction: the reference run
        ref, ref_easies, ref_hard = build(0.0)
        ref.run_to_completion()
        assert ref._pool(None, None).slots == 8
        np.testing.assert_array_equal(
            np.asarray(eng.results[hard].x),
            np.asarray(ref.results[ref_hard].x))
        assert eng.results[hard].iterations == \
            ref.results[ref_hard].iterations
        for r, rr in zip(easies, ref_easies):
            np.testing.assert_array_equal(np.asarray(eng.results[r].x),
                                          np.asarray(ref.results[rr].x))

    def test_admission_regrows_compacted_pool(self):
        """A compacted pool grows its lane bucket back on demand: a new
        submit after compaction is admitted, not rejected."""
        eng = SolverEngine(SolverEngineConfig(
            batch_slots=8, chunk_iters=8, **BK))
        for i in range(7):
            eng.submit(tridiagonal_spd(64 + 8 * i, off=-0.1))
        hard = eng.submit(tridiagonal_spd(500))
        pool = eng._pool(None, None)
        while pool.slots == 8 and pool.any_active:
            eng.step()
        assert pool.slots < 8                     # compaction happened
        assert eng.free_slots() == 7              # capacity view intact
        late = eng.submit(tridiagonal_spd(300))
        assert pool.slots >= 2                    # lanes grew back
        eng.run_to_completion()
        assert eng.results[late].converged
        assert eng.results[hard].converged
