"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret=True.

Every kernel in repro.kernels is swept against its ref.py oracle and
(where applicable) against a dense ground truth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.precision import get_scheme
from repro.kernels.dot import dot3_pallas, dot_pallas
from repro.kernels.fused_phase import phase2_pallas, phase3_pallas
from repro.kernels.ops import ell_operator_pallas
from repro.kernels.ref import (dot3_ref, dot_ref, phase2_ref, phase3_ref,
                               spmv_ref)
from repro.kernels.spmv import spmv_pallas
from repro.sparse import csr_to_dense, diag_dominant_spd, poisson_2d
from repro.sparse.ellpack import csr_to_ellpack

FAST = dict(deadline=None, max_examples=10)


def _tol(dtype):
    return {"float64": 1e-12, "float32": 2e-5, "bfloat16": 2e-1}[
        jnp.dtype(dtype).name]


class TestSpMV:
    @pytest.mark.parametrize("scheme", ["fp64", "mixed_v3", "mixed_v1",
                                        "tpu_v3", "tpu_fp32"])
    @pytest.mark.parametrize("block_rows,col_tile", [(128, 128), (256, 512),
                                                     (8, 128)])
    def test_sweep_vs_oracle(self, scheme, block_rows, col_tile):
        sch = get_scheme(scheme)
        a = poisson_2d(24)                       # n=576
        m = csr_to_ellpack(a, block_rows=block_rows, col_tile=col_tile)
        x = np.random.default_rng(0).standard_normal(a.shape[0])
        xt = jnp.zeros(m.padded_cols, sch.spmv_in_dtype).at[
            : a.shape[0]].set(jnp.asarray(x, sch.spmv_in_dtype))
        xt = xt.reshape(-1, m.col_tile)
        vals = jnp.asarray(m.vals).astype(sch.matrix_dtype)
        tc = jnp.asarray(m.tile_cols)
        lc = jnp.asarray(m.local_cols)
        yk = spmv_pallas(tc, vals, lc, xt, scheme=sch, interpret=True)
        yr = spmv_ref(tc, vals, lc, xt, scheme=sch)
        np.testing.assert_allclose(
            np.asarray(yk, np.float64), np.asarray(yr, np.float64),
            rtol=_tol(sch.spmv_acc_dtype), atol=_tol(sch.spmv_acc_dtype))

    @given(n=st.integers(16, 300), nnz=st.integers(4, 24),
           seed=st.integers(0, 1000))
    @settings(**FAST)
    def test_property_vs_dense(self, n, nnz, seed):
        """Kernel result == dense matvec for random sparse matrices."""
        a = diag_dominant_spd(n, nnz_per_row=nnz, dominance=1.3, seed=seed)
        op = ell_operator_pallas(a, "fp64", block_rows=8, col_tile=128,
                                 interpret=True)
        x = np.random.default_rng(seed).standard_normal(n)
        y = np.asarray(op.matvec(jnp.asarray(x)))
        np.testing.assert_allclose(y, csr_to_dense(a) @ x, rtol=1e-10,
                                   atol=1e-10)

    def test_mixed_v1_rounds_input(self):
        """Mix-V1 casts x to fp32 — the kernel must LOSE the fp64 tail
        (this is the information loss that breaks convergence in Fig. 9)."""
        a = poisson_2d(8)
        op64 = ell_operator_pallas(a, "fp64", block_rows=8, col_tile=128,
                                   interpret=True)
        op1 = ell_operator_pallas(a, "mixed_v1", block_rows=8, col_tile=128,
                                  interpret=True)
        x = jnp.asarray(np.random.default_rng(1).standard_normal(64)
                        * (1 + 1e-12))
        y64 = np.asarray(op64.matvec(x), np.float64)
        y1 = np.asarray(op1.matvec(x), np.float64)
        assert 0 < np.abs(y64 - y1).max() < 1e-4


class TestDot:
    @pytest.mark.parametrize("n", [1, 7, 4096, 4097, 12345])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_dot_sweep(self, n, dtype):
        r = np.random.default_rng(n)
        a = jnp.asarray(r.standard_normal(n), dtype)
        b = jnp.asarray(r.standard_normal(n), dtype)
        got = dot_pallas(a, b, acc_dtype=dtype, interpret=True)
        want = dot_ref(a, b, acc_dtype=dtype)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=_tol(dtype) * 10)

    @pytest.mark.parametrize("n", [5, 4096, 9999])
    def test_dot3_fused(self, n):
        r = np.random.default_rng(n)
        u, v, w = (jnp.asarray(r.standard_normal(n)) for _ in range(3))
        got = dot3_pallas(u, v, w, acc_dtype=jnp.float64, interpret=True)
        want = dot3_ref(u, v, w, acc_dtype=jnp.float64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-10)


class TestFusedPhases:
    @pytest.mark.parametrize("n", [33, 4096, 5001])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_phase2(self, n, dtype):
        r = np.random.default_rng(n)
        rv = jnp.asarray(r.standard_normal(n), dtype)
        ap = jnp.asarray(r.standard_normal(n), dtype)
        dg = jnp.asarray(r.random(n) + 0.5, dtype)
        alpha = jnp.asarray(0.37, dtype)
        rn_k, s_k = phase2_pallas(alpha, rv, ap, dg, interpret=True)
        rn_r, s_r = phase2_ref(alpha, rv, ap, dg)
        np.testing.assert_allclose(np.asarray(rn_k), np.asarray(rn_r),
                                   rtol=_tol(dtype))
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=_tol(dtype) * 100)

    @pytest.mark.parametrize("n", [33, 4096, 5001])
    def test_phase3(self, n):
        r = np.random.default_rng(n)
        args = [jnp.asarray(r.standard_normal(n)) for _ in range(3)]
        dg = jnp.asarray(r.random(n) + 0.5)
        pn_k, xn_k = phase3_pallas(jnp.asarray(0.3), jnp.asarray(0.7),
                                   args[0], dg, args[1], args[2],
                                   interpret=True)
        pn_r, xn_r = phase3_ref(jnp.asarray(0.3), jnp.asarray(0.7),
                                args[0], dg, args[1], args[2])
        np.testing.assert_allclose(np.asarray(pn_k), np.asarray(pn_r),
                                   rtol=1e-12)
        np.testing.assert_allclose(np.asarray(xn_k), np.asarray(xn_r),
                                   rtol=1e-12)

    def test_z_stays_on_chip(self):
        """The phase-2 kernel returns r' and scalars ONLY — z is never an
        output (paper §5.3 'never stored')."""
        out = phase2_pallas(jnp.asarray(0.1), jnp.ones(64), jnp.ones(64),
                            jnp.ones(64), interpret=True)
        assert len(out) == 2                     # (r_new, [rr, rz])


class TestPaddingInvariants:
    @given(n=st.integers(1, 5000))
    @settings(**FAST)
    def test_dot_padding_exact(self, n):
        """Zero padding must not perturb the reduction."""
        a = jnp.ones(n, jnp.float64)
        got = dot_pallas(a, a, acc_dtype=jnp.float64, interpret=True)
        assert float(got) == float(n)

    @given(n=st.integers(2, 2000))
    @settings(**FAST)
    def test_phase2_padding_no_nan(self, n):
        """Padded diag lanes are 1.0 — no NaN leaks from 0/0."""
        rn, s = phase2_pallas(jnp.asarray(1.0), jnp.ones(n), jnp.ones(n),
                              jnp.full(n, 2.0), interpret=True)
        assert np.isfinite(np.asarray(s)).all()
        assert float(s[0]) == pytest.approx(0.0, abs=1e-12)
