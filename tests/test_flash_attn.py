"""Flash-attention kernel vs oracle — shape/dtype/mask sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.kernels.ref import mha_ref

KEY = jax.random.PRNGKey(0)


def _qkv(bh, s, t, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(KEY, 3)
    return (jax.random.normal(k1, (bh, s, d), dtype),
            jax.random.normal(k2, (bh, t, d), dtype),
            jax.random.normal(k3, (bh, t, d), dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("s,bq,bk", [(256, 128, 128), (256, 64, 256),
                                         (512, 128, 512), (128, 128, 128)])
    def test_causal_sweep(self, s, bq, bk):
        q, k, v = _qkv(2, s, s, 64)
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        want = mha_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        q, k, v = _qkv(2, 256, 256, 32)
        got = flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
        want = mha_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        q, k, v = _qkv(1, 128, 256, 64)
        got = flash_attention(q, k, v, causal=False, block_q=64,
                              block_k=128, interpret=True)
        want = mha_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_bf16_io_fp32_stats(self):
        q, k, v = _qkv(2, 256, 256, 64, jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True, block_q=128,
                              block_k=128, interpret=True)
        want = mha_ref(q, k, v, causal=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=3e-2, rtol=3e-2)

    def test_numerical_stability_large_logits(self):
        """Online softmax must survive score magnitudes that overflow a
        naive exp (the running-max rescaling path)."""
        q, k, v = _qkv(1, 128, 128, 32)
        got = flash_attention(30.0 * q, 30.0 * k, v, causal=True,
                              block_q=64, block_k=64, interpret=True)
        want = mha_ref(30.0 * q, 30.0 * k, v, causal=True)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_matches_model_attention(self):
        """End-to-end: kernel == models.attention full path (GQA repeat
        done outside)."""
        from repro.models.attention import attention, init_attention
        d_model, h, hd = 64, 4, 16
        p = init_attention(KEY, d_model, h, h, hd)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 128, d_model))
        want = attention(p, x, n_heads=h, n_kv_heads=h, head_dim=hd,
                         rope_theta=10_000.0)
        # rebuild q/k/v exactly as attention() does, then run the kernel
        from repro.models.attention import _split_heads
        from repro.models.layers import apply_rope, dense, rope_freqs
        q = _split_heads(dense(p["wq"], x), h, hd)
        k = _split_heads(dense(p["wk"], x), h, hd)
        v = _split_heads(dense(p["wv"], x), h, hd)
        cos, sin = rope_freqs(jnp.arange(128)[None], hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        qh = q.transpose(0, 2, 1, 3).reshape(h, 128, hd)
        kh = k.transpose(0, 2, 1, 3).reshape(h, 128, hd)
        vh = v.transpose(0, 2, 1, 3).reshape(h, 128, hd)
        o = flash_attention(qh, kh, vh, causal=True, block_q=64,
                            block_k=64, interpret=True)
        o = o.reshape(1, h, 128, hd).transpose(0, 2, 1, 3)
        got = dense(p["wo"], o.reshape(1, 128, h * hd))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)
