"""Decode engine: consistency with teacher-forced forward, ragged slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import forward_logits, init_params
from repro.models.config import ModelConfig, SSMConfig
from repro.serve import DecodeEngine, EngineConfig, bytes_per_slot

KEY = jax.random.PRNGKey(0)


def _dense(window=None):
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                       head_dim=16, dtype="float32", remat=False,
                       sliding_window=window)


def _ssm():
    return ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                       n_heads=1, n_kv_heads=1, d_ff=0, vocab=128,
                       head_dim=16, dtype="float32", remat=False,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8))


@pytest.mark.parametrize("make_cfg", [_dense, _ssm],
                         ids=["dense", "ssm"])
def test_prefill_matches_teacher_forcing(make_cfg):
    """argmax(prefill logits) == argmax(forward logits at last position)."""
    cfg = make_cfg()
    params = init_params(cfg, KEY)
    prompt = [5, 9, 17, 3, 44, 8]
    toks = jnp.asarray([prompt])
    want = int(jnp.argmax(forward_logits(params, cfg,
                                         {"tokens": toks})[0, -1]))
    eng = DecodeEngine(cfg, params, EngineConfig(batch_slots=1, max_len=32,
                                                 cache_dtype="float32"))
    eng.add_request(prompt, max_new=1)
    assert eng.outputs[0][0] == want


def test_greedy_continuation_matches_rollout():
    """N greedy engine steps == N manual teacher-forced re-evaluations."""
    cfg = _dense()
    params = init_params(cfg, KEY)
    prompt = [7, 21, 3]
    eng = DecodeEngine(cfg, params, EngineConfig(batch_slots=1, max_len=64,
                                                 cache_dtype="float32"))
    eng.add_request(prompt, max_new=6)
    eng.run_to_completion()
    got = eng.outputs[0]
    seq = list(prompt)
    want = []
    for _ in range(6):
        lg = forward_logits(params, cfg, {"tokens": jnp.asarray([seq])})
        t = int(jnp.argmax(lg[0, -1]))
        want.append(t)
        seq.append(t)
    assert got == want


def test_ragged_admission_isolation():
    """Admitting a request mid-flight must not disturb live slots."""
    cfg = _dense()
    params = init_params(cfg, KEY)

    solo = DecodeEngine(cfg, params, EngineConfig(batch_slots=1, max_len=64,
                                                  cache_dtype="float32"))
    solo.add_request([11, 22, 33], max_new=8)
    solo.run_to_completion()

    mixed = DecodeEngine(cfg, params, EngineConfig(batch_slots=3,
                                                   max_len=64,
                                                   cache_dtype="float32"))
    mixed.add_request([11, 22, 33], max_new=8)
    mixed.step()
    mixed.add_request([4, 5], max_new=4)        # joins mid-flight
    mixed.step()
    mixed.add_request([99], max_new=3)
    mixed.run_to_completion()
    assert mixed.outputs[0] == solo.outputs[0]


def test_slot_reuse_after_completion():
    cfg = _dense()
    params = init_params(cfg, KEY)
    eng = DecodeEngine(cfg, params, EngineConfig(batch_slots=1, max_len=64,
                                                 cache_dtype="float32"))
    s0 = eng.add_request([1, 2, 3], max_new=3)
    eng.run_to_completion()
    first = list(eng.outputs[s0])
    s1 = eng.add_request([1, 2, 3], max_new=3)
    eng.run_to_completion()
    assert s1 == s0
    assert eng.outputs[s1] == first             # deterministic + clean slot


def test_eos_frees_slot():
    cfg = _dense()
    params = init_params(cfg, KEY)
    eng = DecodeEngine(cfg, params, EngineConfig(batch_slots=1, max_len=64,
                                                 cache_dtype="float32"))
    eng.add_request([1, 2], max_new=50)
    first = eng.outputs[0][0]
    eng.ecfg = EngineConfig(batch_slots=1, max_len=64, eos_token=first,
                            cache_dtype="float32")
    # run: every generated token == eos candidate ends quickly or max_new
    eng.run_to_completion(max_ticks=60)
    assert not eng.active.any()


def test_windowed_engine_runs():
    """SWA arch decodes past its window with the O(w) ring cache."""
    cfg = _dense(window=8)
    params = init_params(cfg, KEY)
    eng = DecodeEngine(cfg, params, EngineConfig(batch_slots=1, max_len=64,
                                                 cache_dtype="float32"))
    eng.add_request([3, 1, 4, 1, 5], max_new=20)
    outs = eng.run_to_completion()
    assert len(outs[0]) == 20
    assert bytes_per_slot(cfg, 64) < bytes_per_slot(_dense(), 64)


def test_temperature_sampling_deterministic_per_seed():
    cfg = _dense()
    params = init_params(cfg, KEY)

    def run(seed):
        e = DecodeEngine(cfg, params, EngineConfig(
            batch_slots=1, max_len=64, temperature=8.0, seed=seed,
            cache_dtype="float32"))
        e.add_request([9, 8, 7], max_new=24)
        e.run_to_completion()
        return e.outputs[0]

    assert run(0) == run(0)
    assert run(0) != run(1)
