"""Solver property tests (hypothesis) + method equivalences."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.cg import jpcg_solve
from repro.sparse import (csr_to_dense, diag_dominant_spd, poisson_2d,
                          random_spd, tridiagonal_spd)

FAST = dict(deadline=None, max_examples=12)


class TestProperties:
    @given(n=st.integers(8, 200), cond=st.floats(1.5, 1e4),
           seed=st.integers(0, 2**16))
    @settings(**FAST)
    def test_solves_random_spd(self, n, cond, seed):
        """∀ SPD A: JPCG converges and A·x ≈ b (the defining invariant)."""
        a = random_spd(n, cond=cond, seed=seed)
        res = jpcg_solve(a, tol=1e-14, maxiter=20 * n,
                         block_rows=8, col_tile=128)
        d = csr_to_dense(a)
        x = np.asarray(res.x)
        b = np.ones(n)
        assert res.converged
        assert np.linalg.norm(d @ x - b) <= 1e-5 * np.linalg.norm(b) * cond

    @given(n=st.integers(16, 400), seed=st.integers(0, 2**16))
    @settings(**FAST)
    def test_vsr_equals_pipelined(self, n, seed):
        """Paper schedule and beyond-paper pipelined CG agree on x."""
        a = diag_dominant_spd(n, nnz_per_row=8, dominance=1.5, seed=seed)
        r1 = jpcg_solve(a, method="vsr", tol=1e-13, maxiter=10 * n,
                        block_rows=8, col_tile=128)
        r2 = jpcg_solve(a, method="pipelined", tol=1e-13, maxiter=10 * n,
                        block_rows=8, col_tile=128)
        assert r1.converged and r2.converged
        np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                                   rtol=1e-6, atol=1e-9)

    @given(n=st.integers(32, 512))
    @settings(**FAST)
    def test_exact_arithmetic_bound(self, n):
        """CG on the 1-D Laplacian converges within n iterations (theory:
        ≤ n steps in exact arithmetic; Jacobi leaves κ unchanged here)."""
        a = tridiagonal_spd(n)
        res = jpcg_solve(a, tol=1e-10, maxiter=n + 10,
                         block_rows=8, col_tile=128)
        assert res.converged

    @given(scale=st.floats(1e-3, 1e3))
    @settings(**FAST)
    def test_scale_invariant_iterations(self, scale):
        """Jacobi preconditioning ⇒ iteration count invariant to a global
        matrix scaling (residual threshold scales with b)."""
        a = poisson_2d(16)
        base = jpcg_solve(a, tol=1e-12, maxiter=2000, block_rows=8,
                          col_tile=128).iterations
        a2 = a.astype(np.float64)
        a2 = type(a2)(a2.indptr, a2.indices, a2.data * scale, a2.shape)
        b = np.ones(a.shape[0]) * scale
        got = jpcg_solve(a2, b, tol=1e-12 * scale * scale, maxiter=2000,
                         block_rows=8, col_tile=128).iterations
        assert abs(got - base) <= 2


class TestTermination:
    def test_maxiter_respected(self):
        a = diag_dominant_spd(500, nnz_per_row=12, dominance=1.01, seed=1)
        res = jpcg_solve(a, tol=1e-30, maxiter=7, block_rows=8, col_tile=128)
        assert res.iterations == 7 and not res.converged

    def test_on_the_fly_termination(self):
        """One compiled program serves different matrices with different
        iteration counts (paper Challenge 1)."""
        easy = tridiagonal_spd(256, off=-0.1)
        hard = tridiagonal_spd(256)
        r_easy = jpcg_solve(easy, tol=1e-12, maxiter=500, block_rows=8,
                            col_tile=128)
        r_hard = jpcg_solve(hard, tol=1e-12, maxiter=500, block_rows=8,
                            col_tile=128)
        assert r_easy.iterations < r_hard.iterations

    def test_trace_matches_rr(self):
        a = poisson_2d(16)
        res = jpcg_solve(a, tol=1e-12, maxiter=2000, with_trace=True,
                         block_rows=8, col_tile=128)
        assert res.residual_trace.shape[0] == res.iterations
        assert res.residual_trace[-1] == pytest.approx(res.rr)
        assert res.residual_trace[-1] <= 1e-12

    def test_x0_respected(self):
        """Starting at the solution terminates immediately."""
        a = poisson_2d(12)
        d = csr_to_dense(a)
        xstar = np.linalg.solve(d, np.ones(a.shape[0]))
        res = jpcg_solve(a, x0=xstar, tol=1e-10, maxiter=100,
                         block_rows=8, col_tile=128)
        assert res.iterations <= 1


class TestBackends:
    def test_pallas_backend_matches_xla(self):
        a = poisson_2d(24)
        r_x = jpcg_solve(a, backend="xla", tol=1e-12, maxiter=2000,
                         block_rows=64, col_tile=128)
        r_p = jpcg_solve(a, backend="pallas", tol=1e-12, maxiter=2000,
                         block_rows=128, col_tile=128)
        assert r_x.iterations == r_p.iterations
        np.testing.assert_allclose(np.asarray(r_x.x), np.asarray(r_p.x),
                                   rtol=1e-9)

    def test_matrix_free_operator(self):
        """Callable A (the CGGN path) solves like the explicit matrix."""
        import jax.numpy as jnp
        a = random_spd(64, cond=100.0, seed=7)
        d = csr_to_dense(a)
        dj = jnp.asarray(d)
        res = jpcg_solve(lambda v: dj @ v, diag=np.diag(d), n=64,
                         tol=1e-13, maxiter=1000)
        x = np.linalg.solve(d, np.ones(64))
        np.testing.assert_allclose(np.asarray(res.x), x, rtol=1e-5,
                                   atol=1e-7)

    def test_dense_operator(self):
        a = random_spd(48, cond=50.0, seed=3)
        d = csr_to_dense(a)
        res = jpcg_solve(d, scheme="fp64", tol=1e-20, maxiter=2000)
        np.testing.assert_allclose(
            np.asarray(res.x), np.linalg.solve(d, np.ones(48)),
            rtol=1e-6, atol=1e-7)


@pytest.mark.slow
def test_residual_replacement_stabilizes_pipelined():
    """Pipelined CG with periodic residual replacement reaches the same
    tolerance as true-residual CG on an ill-conditioned system."""
    a = diag_dominant_spd(2000, nnz_per_row=16, dominance=1.01, seed=4)
    r = jpcg_solve(a, method="pipelined", replace_every=50, tol=1e-12,
                   maxiter=20000, block_rows=64, col_tile=128)
    assert r.converged
