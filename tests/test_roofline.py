"""Roofline machinery: HLO cost walker (loop multiplicity), byte models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_bytes import collective_bytes, parse_collectives
from repro.roofline.hlo_cost import walk_hlo
from repro.roofline.model import (V5E, model_flops_train, roofline_terms)


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


class TestWalker:
    def test_dot_flops_exact(self):
        c = _compile(lambda a, b: a @ b,
                     jax.ShapeDtypeStruct((64, 128), jnp.float32),
                     jax.ShapeDtypeStruct((128, 32), jnp.float32))
        w = walk_hlo(c.as_text())
        assert w.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.05)

    def test_scan_multiplicity(self):
        """A 13-iteration scan body counts ×13 — the cost_analysis bug
        this walker exists to fix."""
        def f(x):
            def body(c, _):
                return jnp.tanh(c @ c), None
            y, _ = jax.lax.scan(body, x, None, length=13)
            return y

        c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
        w = walk_hlo(c.as_text())
        assert w.flops == pytest.approx(13 * 2 * 64 ** 3, rel=0.05)
        assert w.transcendentals == pytest.approx(13 * 64 * 64, rel=0.01)
        ca = c.cost_analysis()          # dict (new jax) or [dict] (old jax)
        xla = dict(ca[0] if isinstance(ca, (list, tuple)) else ca)
        assert xla["flops"] < w.flops / 5       # the bug being fixed

    def test_nested_scans_multiply(self):
        def f(x):
            def outer(c, _):
                def inner(d, _):
                    return d @ d, None
                d, _ = jax.lax.scan(inner, c, None, length=3)
                return d, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y

        c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
        w = walk_hlo(c.as_text())
        assert w.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.1)

    def test_bytes_scale_with_loops(self):
        def f(x):
            def body(c, _):
                return c * 2.0 + 1.0, None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y

        c = _compile(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
        w = walk_hlo(c.as_text())
        # ≥ 10 × (read + write) of 4 MB
        assert w.hbm_bytes >= 10 * 2 * 4 * 1024 * 1024 * 0.9


class TestCollectiveModel:
    def test_parse_and_byte_model(self):
        hlo = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %ar = f32[64,256]{1,0} all-reduce(%x), replica_groups=[4,2]<=[8]
  %ag = f32[64,256]{1,0} all-gather(%y), replica_groups=[2,4]<=[8]
  %cp = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
}
"""
        ops = parse_collectives(hlo, default_group=8)
        assert len(ops) == 3
        ar, ag, cp = ops
        rb = 64 * 256 * 4
        assert ar.kind == "all-reduce" and ar.group_size == 2
        assert ar.wire_bytes == int(2 * 0.5 * rb)
        assert ag.group_size == 4
        assert ag.wire_bytes == int(0.75 * rb)
        assert cp.wire_bytes == 8 * 8 * 4
        agg = collective_bytes(hlo, 8)
        assert agg["n_ops"] == 3

    def test_real_allreduce_counted(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        if jax.device_count() < 2:
            pytest.skip("single-device session")
        mesh = jax.make_mesh((jax.device_count(),), ("d",))
        s = NamedSharding(mesh, P(None, "d"))
        c = _compile(lambda a, b: a @ b,
                     jax.ShapeDtypeStruct((32, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 32), jnp.float32),
                     in_shardings=(s, NamedSharding(mesh, P("d", None))),
                     out_shardings=NamedSharding(mesh, P()))
        w = walk_hlo(c.as_text(), default_group=jax.device_count())
        assert w.collective_count >= 1 and w.wire_bytes > 0


class TestModel:
    def test_terms_and_dominance(self):
        t = roofline_terms({"flops": 197e12, "bytes accessed": 819e9},
                           wire_bytes=0.0)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.dominant in ("compute", "memory")
        t2 = roofline_terms({"flops": 1.0, "bytes accessed": 1.0},
                            wire_bytes=200e9 * 10)
        assert t2.dominant == "collective"
        assert t2.collective_s == pytest.approx(10.0)

    def test_useful_fraction(self):
        t = roofline_terms({"flops": 1e12, "bytes accessed": 1.0},
                           wire_bytes=0.0, chips=256,
                           model_flops=128e12)
        assert t.useful_fraction == pytest.approx(0.5)

    def test_v5e_constants(self):
        assert V5E.peak_bf16_flops == 197e12
        assert V5E.hbm_bw == 819e9
        assert V5E.ici_link_bw == 50e9
        assert model_flops_train(1e9, 1e6) == 6e15


class TestDryrunArtifacts:
    """Validate the committed dry-run artifacts if present."""

    def test_single_pod_artifacts(self):
        import json
        import os
        d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "dryrun", "single")
        if not os.path.isdir(d):
            pytest.skip("dry-run artifacts not generated yet")
        recs = [json.load(open(os.path.join(d, f)))
                for f in os.listdir(d) if f.endswith(".json")]
        assert len(recs) >= 30
        for r in recs:
            assert r["status"] == "OK", r
            assert r["chips"] == 256
            t = r["roofline"]
            assert t["compute_s"] > 0 and t["memory_s"] > 0
            assert r["fits_hbm"], (r["arch"], r["shape"],
                                   r["memory"]["total_bytes"])
